//! The distributed transport: length-prefixed, checksummed frames between
//! shard processes and the L3 coordinator, plus the compact wire codec for
//! FN messages and delta-encoded walk state.
//!
//! The shard-per-process engine keeps the BSP structure of
//! [`super::engine`] untouched: workers still exchange messages through
//! per-worker inboxes, and only the *shard boundary* is crossed by this
//! module. Topology is hub-and-spoke — every shard holds exactly one
//! duplex connection to the coordinator, which forwards cross-shard data
//! frames and multiplexes control (barrier reports, decisions, checkpoint
//! parts) on the same ordered stream. That single-connection discipline is
//! what makes the ordering argument in `coordinator/` airtight: all frames
//! a shard sends are observed in send order, and the coordinator never
//! emits a superstep decision before it has forwarded every data frame of
//! that superstep.
//!
//! # Frame layout (all little-endian)
//!
//! | bytes  | field                                         |
//! |--------|-----------------------------------------------|
//! | 0..4   | magic `"FN2T"`                                |
//! | 4      | kind ([`FrameKind`])                          |
//! | 5      | source shard                                  |
//! | 6      | destination shard                             |
//! | 7      | sequence number (per direction, mod 256)      |
//! | 8..12  | superstep                                     |
//! | 12..16 | payload length                                |
//! | 16..24 | fxhash64 of the payload                       |
//!
//! Validation mirrors the FN2VGRF2 store: magic → kind → length bound →
//! payload checksum, each failure a typed [`FrameError`]. The two
//! [`Transport`] implementations share the codec byte-for-byte: the
//! in-process channel transport carries fully *encoded* frames through an
//! `mpsc` pair, so checksums and decode paths are exercised identically
//! whether shards are threads or processes.
//!
//! # Sequence numbers
//!
//! Byte 7 carries a per-connection, per-direction sequence number: the
//! sender stamps frames 0, 1, 2, … (mod 256) in [`Transport::encode_outgoing`]
//! and the receiver verifies the counter in `recv`, surfacing a gap or a
//! replay as a typed [`FrameError::BadSeq`]. The checksum only proves a
//! frame arrived *intact*; the sequence number proves the *stream* is
//! intact — a silently dropped or duplicated `Data` frame would otherwise
//! corrupt walks without tripping any check. The raw codec
//! ([`encode_frame`] / [`decode_frame`]) is sequence-agnostic (it writes 0
//! and ignores the byte on parse); stamping and verification live in the
//! transports, next to the stream state they protect.
//!
//! # Chaos injection
//!
//! [`ChaosTransport`] decorates any [`Transport`] with a deterministic,
//! seed-derived schedule of send-side faults — drops, duplicates, delays,
//! payload/checksum flips, truncations — so every failure mode the static
//! corrupt-frame matrix covers is also exercised *mid-run* against the
//! live supervision layer in `coordinator/`. Mutations are applied after
//! sequence stamping (a dropped frame leaves a hole the receiver can see)
//! and never touch header bytes 0..16 (a flipped superstep could be
//! accepted as valid routing and silently corrupt delivery; a flipped
//! payload or checksum byte is always a typed `BadChecksum`).
//!
//! # Wire message entries
//!
//! Cross-shard FN messages travel inside `Data` frames as a sequence of
//! entries: `[entry_len: u32][dst: u32][encoded message]`. The encoded
//! message is exactly [`crate::pregel::Message::wire_bytes`] bytes — the
//! simulated wire size the engine has always charged — and
//! [`encode_entry`] debug-asserts that equality, so the measured
//! `bytes_remote` metric and the self-reported accounting can never drift
//! apart silently. The `dst` and `entry_len` words are routing/framing
//! overhead on top of the simulated size (4 + 4 bytes per entry).
//!
//! Walk state shipped back to the coordinator at the end of a unit is
//! delta-encoded ([`encode_walk_delta`]): consecutive walk vertices are
//! zigzag-varint deltas from the previous vertex, which compresses the
//! locality-heavy walks FN produces far below raw 4-byte ids.

use std::io::{Read, Write};
use std::os::unix::net::UnixStream;
use crate::util::sync::mpsc::{Receiver, Sender};

use crate::graph::store::fxhash64;
use crate::graph::VertexId;
use crate::util::failpoints;

use super::checkpoint::ByteReader;
use super::Message;

/// Frame magic: `"FN2T"` (FN2V transport).
pub const FRAME_MAGIC: u32 = u32::from_le_bytes(*b"FN2T");

/// Fixed frame header size in bytes.
pub const FRAME_HEADER_BYTES: usize = 24;

/// Upper bound on a frame payload; anything larger is a protocol error
/// (a corrupt length field must not trigger a giant allocation).
pub const MAX_FRAME_BYTES: u32 = 1 << 30;

/// What a frame carries. The numeric tags are part of the wire format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Shard → coordinator on connect: shard id + graph shape check.
    Hello = 1,
    /// Coordinator → shard: parameters of one engine unit to run.
    Run = 2,
    /// Shard → shard (via coordinator): encoded cross-shard messages.
    Data = 3,
    /// Shard → coordinator: end-of-superstep report ([`ShardReport`]).
    Barrier = 4,
    /// Coordinator → shard: superstep [`Decision`].
    Decision = 5,
    /// Shard → coordinator: this shard's encoded checkpoint part.
    CkptPart = 6,
    /// Coordinator → shard: checkpoint write outcome.
    CkptResult = 7,
    /// Shard → coordinator: final walks + stats of a finished unit.
    Values = 8,
    /// Shard → coordinator: local failure (worker panic etc.).
    Error = 9,
    /// Coordinator → shard: exit the serve loop.
    Shutdown = 10,
    /// Shard → coordinator: periodic liveness beacon (empty payload).
    Heartbeat = 11,
}

impl FrameKind {
    pub fn from_u8(tag: u8) -> Option<FrameKind> {
        Some(match tag {
            1 => FrameKind::Hello,
            2 => FrameKind::Run,
            3 => FrameKind::Data,
            4 => FrameKind::Barrier,
            5 => FrameKind::Decision,
            6 => FrameKind::CkptPart,
            7 => FrameKind::CkptResult,
            8 => FrameKind::Values,
            9 => FrameKind::Error,
            10 => FrameKind::Shutdown,
            11 => FrameKind::Heartbeat,
            _ => return None,
        })
    }
}

/// One transport frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub kind: FrameKind,
    /// Source shard (coordinator uses `u8::MAX`).
    pub src: u8,
    /// Destination shard (coordinator uses `u8::MAX`).
    pub dst: u8,
    pub superstep: u32,
    pub payload: Vec<u8>,
}

/// The coordinator's shard id in `src`/`dst` fields.
pub const COORD_ID: u8 = u8::MAX;

impl Frame {
    pub fn new(kind: FrameKind, src: u8, dst: u8, superstep: u32, payload: Vec<u8>) -> Frame {
        Frame {
            kind,
            src,
            dst,
            superstep,
            payload,
        }
    }
}

/// Typed frame decode/transport failures, mirroring the corrupt-file
/// matrix style of `graph::store`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// First four bytes are not `"FN2T"`.
    BadMagic { got: u32 },
    /// Unknown [`FrameKind`] tag.
    BadKind { got: u8 },
    /// Payload length exceeds [`MAX_FRAME_BYTES`].
    TooLarge { len: u32 },
    /// Stream or buffer ended mid-frame.
    Truncated { needed: usize, got: usize },
    /// Payload checksum mismatch.
    BadChecksum { expected: u64, got: u64 },
    /// Per-direction sequence counter mismatch: a frame was dropped,
    /// duplicated, or reordered somewhere on the connection.
    BadSeq { expected: u8, got: u8 },
    /// Underlying I/O failure.
    Io(String),
    /// Peer closed the connection at a frame boundary.
    Closed,
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::BadMagic { got } => {
                write!(f, "bad frame magic {got:#010x} (expected \"FN2T\")")
            }
            FrameError::BadKind { got } => write!(f, "unknown frame kind tag {got}"),
            FrameError::TooLarge { len } => {
                write!(f, "frame payload of {len} bytes exceeds {MAX_FRAME_BYTES}")
            }
            FrameError::Truncated { needed, got } => {
                write!(f, "truncated frame: needed {needed} bytes, got {got}")
            }
            FrameError::BadChecksum { expected, got } => write!(
                f,
                "frame payload checksum mismatch: header says {expected:#018x}, payload hashes to {got:#018x}"
            ),
            FrameError::BadSeq { expected, got } => write!(
                f,
                "frame sequence mismatch: expected {expected}, got {got} (dropped, duplicated, or reordered frame)"
            ),
            FrameError::Io(detail) => write!(f, "transport I/O error: {detail}"),
            FrameError::Closed => write!(f, "transport connection closed"),
        }
    }
}

impl std::error::Error for FrameError {}

/// Encode a frame (header + payload) into a fresh buffer. The sequence
/// byte is written as 0; [`Transport::encode_outgoing`] stamps the live
/// counter on the actual send path.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER_BYTES + frame.payload.len());
    out.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
    out.push(frame.kind as u8);
    out.push(frame.src);
    out.push(frame.dst);
    out.push(0);
    out.extend_from_slice(&frame.superstep.to_le_bytes());
    out.extend_from_slice(&(frame.payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&fxhash64(&frame.payload).to_le_bytes());
    out.extend_from_slice(&frame.payload);
    out
}

/// Parsed header fields: (kind, src, dst, superstep, payload_len, checksum).
fn parse_header(h: &[u8; FRAME_HEADER_BYTES]) -> Result<(FrameKind, u8, u8, u32, u32, u64), FrameError> {
    let magic = u32::from_le_bytes([h[0], h[1], h[2], h[3]]);
    if magic != FRAME_MAGIC {
        return Err(FrameError::BadMagic { got: magic });
    }
    let kind = FrameKind::from_u8(h[4]).ok_or(FrameError::BadKind { got: h[4] })?;
    let superstep = u32::from_le_bytes([h[8], h[9], h[10], h[11]]);
    let len = u32::from_le_bytes([h[12], h[13], h[14], h[15]]);
    if len > MAX_FRAME_BYTES {
        return Err(FrameError::TooLarge { len });
    }
    let sum = u64::from_le_bytes([h[16], h[17], h[18], h[19], h[20], h[21], h[22], h[23]]);
    Ok((kind, h[5], h[6], superstep, len, sum))
}

/// Decode one frame from a complete buffer (the channel transport's path;
/// also the unit under test for the corrupt-frame matrix).
pub fn decode_frame(buf: &[u8]) -> Result<Frame, FrameError> {
    if buf.len() < FRAME_HEADER_BYTES {
        return Err(FrameError::Truncated {
            needed: FRAME_HEADER_BYTES,
            got: buf.len(),
        });
    }
    let mut h = [0u8; FRAME_HEADER_BYTES];
    h.copy_from_slice(&buf[..FRAME_HEADER_BYTES]);
    let (kind, src, dst, superstep, len, expected) = parse_header(&h)?;
    let total = FRAME_HEADER_BYTES + len as usize;
    if buf.len() < total {
        return Err(FrameError::Truncated {
            needed: total,
            got: buf.len(),
        });
    }
    let payload = &buf[FRAME_HEADER_BYTES..total];
    let got = fxhash64(payload);
    if got != expected {
        return Err(FrameError::BadChecksum { expected, got });
    }
    Ok(Frame {
        kind,
        src,
        dst,
        superstep,
        payload: payload.to_vec(),
    })
}

/// A duplex frame connection. Implementations must preserve send order
/// (the barrier protocol's correctness argument leans on FIFO delivery).
///
/// The send path is split into [`Transport::encode_outgoing`] (encode +
/// stamp the next tx sequence number) and [`Transport::send_bytes`]
/// (write pre-encoded bytes), with `send` as their composition. The
/// split exists for [`ChaosTransport`]: a chaos-dropped frame must still
/// consume a sequence number so the receiver can detect the hole.
pub trait Transport: Send {
    fn send(&mut self, frame: &Frame) -> Result<(), FrameError>;
    fn recv(&mut self) -> Result<Frame, FrameError>;
    /// Encode `frame` and stamp the next outgoing sequence number,
    /// without sending anything.
    fn encode_outgoing(&mut self, frame: &Frame) -> Vec<u8>;
    /// Send bytes produced by [`Transport::encode_outgoing`].
    fn send_bytes(&mut self, bytes: Vec<u8>) -> Result<(), FrameError>;
    /// Split into independent (reader, writer) halves so the coordinator
    /// can pump each direction from its own thread. The reader half
    /// inherits the receive sequence counter, the writer half the send
    /// counter, so the per-direction streams continue unbroken.
    fn split(self: Box<Self>) -> Result<(Box<dyn Transport>, Box<dyn Transport>), FrameError>;
}

/// Stamp the per-direction sequence counter into header byte 7.
fn stamp_seq(bytes: &mut [u8], seq: &mut u8) {
    bytes[7] = *seq;
    *seq = seq.wrapping_add(1);
}

/// Verify a received frame's sequence byte against the expected counter.
fn check_seq(got: u8, seq: &mut u8) -> Result<(), FrameError> {
    let expected = *seq;
    *seq = seq.wrapping_add(1);
    if got != expected {
        return Err(FrameError::BadSeq { expected, got });
    }
    Ok(())
}

/// In-process transport: an `mpsc` pair carrying fully encoded frames, so
/// the codec (checksums included) runs exactly as it does over a socket.
pub struct ChanTransport {
    tx: Option<Sender<Vec<u8>>>,
    rx: Option<Receiver<Vec<u8>>>,
    tx_seq: u8,
    rx_seq: u8,
}

impl ChanTransport {
    /// A connected duplex pair.
    pub fn pair() -> (ChanTransport, ChanTransport) {
        let (atx, brx) = crate::util::sync::mpsc::channel();
        let (btx, arx) = crate::util::sync::mpsc::channel();
        (
            ChanTransport {
                tx: Some(atx),
                rx: Some(arx),
                tx_seq: 0,
                rx_seq: 0,
            },
            ChanTransport {
                tx: Some(btx),
                rx: Some(brx),
                tx_seq: 0,
                rx_seq: 0,
            },
        )
    }
}

impl Transport for ChanTransport {
    fn send(&mut self, frame: &Frame) -> Result<(), FrameError> {
        let bytes = self.encode_outgoing(frame);
        self.send_bytes(bytes)
    }

    fn encode_outgoing(&mut self, frame: &Frame) -> Vec<u8> {
        let mut bytes = encode_frame(frame);
        stamp_seq(&mut bytes, &mut self.tx_seq);
        bytes
    }

    fn send_bytes(&mut self, bytes: Vec<u8>) -> Result<(), FrameError> {
        failpoints::retry_io("transport.write", || failpoints::check("transport.write"))
            .map_err(|e| FrameError::Io(e.to_string()))?;
        let tx = self.tx.as_ref().ok_or(FrameError::Closed)?;
        tx.send(bytes).map_err(|_| FrameError::Closed)
    }

    fn recv(&mut self) -> Result<Frame, FrameError> {
        failpoints::retry_io("transport.read", || failpoints::check("transport.read"))
            .map_err(|e| FrameError::Io(e.to_string()))?;
        let rx = self.rx.as_ref().ok_or(FrameError::Closed)?;
        let bytes = rx.recv().map_err(|_| FrameError::Closed)?;
        let frame = decode_frame(&bytes)?;
        check_seq(bytes[7], &mut self.rx_seq)?;
        Ok(frame)
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn Transport>, Box<dyn Transport>), FrameError> {
        Ok((
            Box::new(ChanTransport {
                tx: None,
                rx: self.rx,
                tx_seq: 0,
                rx_seq: self.rx_seq,
            }),
            Box::new(ChanTransport {
                tx: self.tx,
                rx: None,
                tx_seq: self.tx_seq,
                rx_seq: 0,
            }),
        ))
    }
}

/// Unix-domain-socket transport between shard processes and the
/// coordinator. EINTR/partial reads are absorbed by [`failpoints::retry_io`]
/// around every syscall, which is also where the fault-injection suite
/// drives the `transport.read` / `transport.write` sites.
pub struct UdsTransport {
    stream: UnixStream,
    tx_seq: u8,
    rx_seq: u8,
}

impl UdsTransport {
    pub fn new(stream: UnixStream) -> UdsTransport {
        UdsTransport {
            stream,
            tx_seq: 0,
            rx_seq: 0,
        }
    }
}

/// Fill `buf` from `stream`. `Ok(false)` when the peer closed cleanly
/// before the first byte (and `allow_eof` is set); a close mid-buffer is
/// always a [`FrameError::Truncated`].
fn read_full(stream: &mut UnixStream, buf: &mut [u8], allow_eof: bool) -> Result<bool, FrameError> {
    let mut filled = 0usize;
    while filled < buf.len() {
        let n = failpoints::retry_io("transport.read", || stream.read(&mut buf[filled..]))
            .map_err(|e| FrameError::Io(e.to_string()))?;
        if n == 0 {
            if filled == 0 && allow_eof {
                return Ok(false);
            }
            return Err(FrameError::Truncated {
                needed: buf.len(),
                got: filled,
            });
        }
        filled += n;
    }
    Ok(true)
}

impl Transport for UdsTransport {
    fn send(&mut self, frame: &Frame) -> Result<(), FrameError> {
        let bytes = self.encode_outgoing(frame);
        self.send_bytes(bytes)
    }

    fn encode_outgoing(&mut self, frame: &Frame) -> Vec<u8> {
        let mut bytes = encode_frame(frame);
        stamp_seq(&mut bytes, &mut self.tx_seq);
        bytes
    }

    fn send_bytes(&mut self, bytes: Vec<u8>) -> Result<(), FrameError> {
        failpoints::retry_io("transport.write", || self.stream.write_all(&bytes))
            .map_err(|e| FrameError::Io(e.to_string()))?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Frame, FrameError> {
        let mut header = [0u8; FRAME_HEADER_BYTES];
        if !read_full(&mut self.stream, &mut header, true)? {
            return Err(FrameError::Closed);
        }
        let (kind, src, dst, superstep, len, expected) = parse_header(&header)?;
        let mut payload = vec![0u8; len as usize];
        read_full(&mut self.stream, &mut payload, false)?;
        let got = fxhash64(&payload);
        if got != expected {
            return Err(FrameError::BadChecksum { expected, got });
        }
        check_seq(header[7], &mut self.rx_seq)?;
        Ok(Frame {
            kind,
            src,
            dst,
            superstep,
            payload,
        })
    }

    fn split(mut self: Box<Self>) -> Result<(Box<dyn Transport>, Box<dyn Transport>), FrameError> {
        let clone = self
            .stream
            .try_clone()
            .map_err(|e| FrameError::Io(format!("clone socket: {e}")))?;
        let reader = UdsTransport {
            stream: clone,
            tx_seq: 0,
            rx_seq: self.rx_seq,
        };
        self.rx_seq = 0;
        Ok((Box::new(reader), self))
    }
}

// ---------------------------------------------------------------------------
// Chaos injection
// ---------------------------------------------------------------------------

/// Chaos stream salt (distinct from every other RNG stream salt in the
/// tree so chaos draws can never collide with sampling draws).
const CHAOS_SALT: u64 = 0xC4A0_5FA7;

/// Direction tag for a coordinator → shard chaos stream.
pub const CHAOS_DIR_TO_SHARD: u8 = 0;
/// Direction tag for a shard → coordinator chaos stream.
pub const CHAOS_DIR_TO_COORD: u8 = 1;

/// A deterministic schedule of send-side transport faults. Rates are
/// per-mille per eligible frame; the draw for frame `i` on a connection is
/// a pure function of `(seed, shard, direction, generation, i)`, so a
/// given config replays the same schedule every run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChaosConfig {
    pub seed: u64,
    /// Per-mille probability that a frame is silently discarded (the
    /// receiver sees the sequence hole on the next frame).
    pub drop_pm: u32,
    /// Per-mille probability that a frame is sent twice (the duplicate
    /// carries a stale sequence number).
    pub dup_pm: u32,
    /// Per-mille probability that a frame is delayed by `delay_ms`.
    pub delay_pm: u32,
    /// Per-mille probability that one payload (or, for empty payloads,
    /// checksum) byte is flipped.
    pub flip_pm: u32,
    /// Per-mille probability that the encoded frame is truncated to half
    /// its length.
    pub trunc_pm: u32,
    /// Delay applied by a `delay` event, in milliseconds.
    pub delay_ms: u64,
    /// Flip a payload byte of exactly the n-th `Data` frame sent on a
    /// generation-0 connection: the deterministic single-corruption used
    /// by the mid-run corrupt-frame test (respawned fleets run clean).
    pub flip_data_nth: Option<u64>,
}

impl ChaosConfig {
    pub fn new(seed: u64) -> ChaosConfig {
        ChaosConfig {
            seed,
            ..ChaosConfig::default()
        }
    }

    /// The soak-test preset: every mutation class enabled at rates that
    /// produce roughly one or two faults per small test run — enough to
    /// force recovery without starving forward progress.
    pub fn light(seed: u64) -> ChaosConfig {
        ChaosConfig {
            seed,
            drop_pm: 4,
            dup_pm: 4,
            delay_pm: 4,
            flip_pm: 4,
            trunc_pm: 2,
            delay_ms: 2,
            flip_data_nth: None,
        }
    }

    pub fn with_flip_data_nth(mut self, nth: u64) -> ChaosConfig {
        self.flip_data_nth = Some(nth);
        self
    }
}

/// What chaos does to one outgoing frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Mutation {
    Pass,
    Drop,
    Dup,
    Delay,
    Flip,
    Trunc,
}

/// Seeded fault-injecting decorator over any [`Transport`].
///
/// Chaos applies on the *send* side only (wrap both endpoints to cover
/// both directions) and always after sequence stamping, so a dropped or
/// duplicated frame is detectable at the receiver as [`FrameError::BadSeq`].
/// `Hello` and `Shutdown` frames are exempt: the handshake and teardown
/// paths are supervised by timeouts, not by the respawn loop, and chaos
/// there would only slow tests down without exercising new recovery code.
pub struct ChaosTransport {
    inner: Box<dyn Transport>,
    cfg: ChaosConfig,
    shard: u8,
    dir: u8,
    generation: u64,
    /// Frames offered to chaos on this connection (exempt frames count,
    /// so the schedule is independent of frame-kind mix).
    sent: u64,
    /// `Data` frames sent on this connection (for `flip_data_nth`).
    data_sent: u64,
}

impl ChaosTransport {
    /// Wrap `inner` with the chaos stream identified by
    /// `(shard, dir, generation)` — `dir` is one of
    /// [`CHAOS_DIR_TO_SHARD`] / [`CHAOS_DIR_TO_COORD`]. Generation feeds
    /// the schedule so a respawned fleet draws a fresh schedule instead
    /// of deterministically re-hitting the fault that killed it.
    pub fn wrap(
        inner: Box<dyn Transport>,
        cfg: ChaosConfig,
        shard: u8,
        dir: u8,
        generation: u64,
    ) -> Box<dyn Transport> {
        Box::new(ChaosTransport {
            inner,
            cfg,
            shard,
            dir,
            generation,
            sent: 0,
            data_sent: 0,
        })
    }

    fn stream_id(&self) -> u64 {
        (self.generation << 16) | ((self.dir as u64) << 8) | self.shard as u64
    }

    fn mutation_for(&self, idx: u64) -> Mutation {
        let c = &self.cfg;
        let total = c.drop_pm + c.dup_pm + c.delay_pm + c.flip_pm + c.trunc_pm;
        if total == 0 {
            return Mutation::Pass;
        }
        let roll =
            crate::util::rng::stream(c.seed, self.stream_id(), idx, CHAOS_SALT).next_bounded(1000);
        let roll = roll as u32;
        if roll < c.drop_pm {
            Mutation::Drop
        } else if roll < c.drop_pm + c.dup_pm {
            Mutation::Dup
        } else if roll < c.drop_pm + c.dup_pm + c.delay_pm {
            Mutation::Delay
        } else if roll < c.drop_pm + c.dup_pm + c.delay_pm + c.flip_pm {
            Mutation::Flip
        } else if roll < total {
            Mutation::Trunc
        } else {
            Mutation::Pass
        }
    }

    /// Flip one byte in the payload region (or a checksum byte when the
    /// payload is empty) — never bytes 0..16, where a flip could survive
    /// validation as plausible routing and corrupt delivery silently.
    fn flip_byte(&self, bytes: &mut [u8], idx: u64) {
        let mut draw =
            crate::util::rng::stream(self.cfg.seed, self.stream_id(), idx, CHAOS_SALT ^ 1);
        let offset = if bytes.len() > FRAME_HEADER_BYTES {
            let span = (bytes.len() - FRAME_HEADER_BYTES) as u64;
            FRAME_HEADER_BYTES + draw.next_bounded(span) as usize
        } else {
            16 + draw.next_bounded(8) as usize
        };
        bytes[offset] ^= 0x01;
    }
}

impl Transport for ChaosTransport {
    fn send(&mut self, frame: &Frame) -> Result<(), FrameError> {
        let mut bytes = self.inner.encode_outgoing(frame);
        let idx = self.sent;
        self.sent += 1;
        if matches!(frame.kind, FrameKind::Hello | FrameKind::Shutdown) {
            return self.inner.send_bytes(bytes);
        }
        if frame.kind == FrameKind::Data {
            let nth = self.data_sent;
            self.data_sent += 1;
            if self.generation == 0 && self.cfg.flip_data_nth == Some(nth) {
                self.flip_byte(&mut bytes, idx);
                return self.inner.send_bytes(bytes);
            }
        }
        match self.mutation_for(idx) {
            Mutation::Pass => self.inner.send_bytes(bytes),
            Mutation::Drop => Ok(()),
            Mutation::Dup => {
                self.inner.send_bytes(bytes.clone())?;
                self.inner.send_bytes(bytes)
            }
            Mutation::Delay => {
                crate::util::sync::thread::sleep(std::time::Duration::from_millis(
                    self.cfg.delay_ms,
                ));
                self.inner.send_bytes(bytes)
            }
            Mutation::Flip => {
                self.flip_byte(&mut bytes, idx);
                self.inner.send_bytes(bytes)
            }
            Mutation::Trunc => {
                let half = bytes.len() / 2;
                bytes.truncate(half);
                self.inner.send_bytes(bytes)
            }
        }
    }

    fn recv(&mut self) -> Result<Frame, FrameError> {
        self.inner.recv()
    }

    fn encode_outgoing(&mut self, frame: &Frame) -> Vec<u8> {
        self.inner.encode_outgoing(frame)
    }

    fn send_bytes(&mut self, bytes: Vec<u8>) -> Result<(), FrameError> {
        self.inner.send_bytes(bytes)
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn Transport>, Box<dyn Transport>), FrameError> {
        let me = *self;
        let (reader, writer) = me.inner.split()?;
        let chaos_writer = ChaosTransport {
            inner: writer,
            cfg: me.cfg,
            shard: me.shard,
            dir: me.dir,
            generation: me.generation,
            sent: me.sent,
            data_sent: me.data_sent,
        };
        Ok((reader, Box::new(chaos_writer)))
    }
}

// ---------------------------------------------------------------------------
// Wire message entries
// ---------------------------------------------------------------------------

/// A message that can cross a shard boundary. `encode_wire` must write
/// *exactly* [`Message::wire_bytes`] bytes — the engine has always charged
/// that simulated size, and [`encode_entry`] asserts the codec agrees.
pub trait WireMsg: Message + Sized {
    fn encode_wire(&self, out: &mut Vec<u8>);
    /// Decode one message from a bounded entry body (everything after the
    /// `dst` word); the body length disambiguates variable-size variants.
    fn decode_wire(r: &mut ByteReader<'_>) -> Result<Self, String>;
}

/// Append one `[entry_len][dst][msg]` entry; returns the bytes written
/// (framing included). Debug-asserts the codec size against
/// `Msg::wire_bytes()` so `BENCH_walks.json`'s wire-byte numbers cannot
/// silently drift from what actually crosses the transport.
pub fn encode_entry<M: WireMsg>(dst: VertexId, msg: &M, out: &mut Vec<u8>) -> u64 {
    let at = out.len();
    out.extend_from_slice(&0u32.to_le_bytes()); // entry_len, patched below
    out.extend_from_slice(&dst.to_le_bytes());
    msg.encode_wire(out);
    let body = (out.len() - at - 4) as u64;
    debug_assert_eq!(
        body - 4,
        msg.wire_bytes(),
        "wire codec size and Msg::wire_bytes() disagree"
    );
    let len = (body as u32).to_le_bytes();
    out[at..at + 4].copy_from_slice(&len);
    body + 4
}

/// Decode one entry written by [`encode_entry`].
pub fn decode_entry<M: WireMsg>(r: &mut ByteReader<'_>) -> Result<(VertexId, M), String> {
    let len = r.u32()? as usize;
    let body = r.take(len)?;
    let mut br = ByteReader::new(body);
    let dst = br.u32()?;
    let msg = M::decode_wire(&mut br)?;
    if !br.is_empty() {
        return Err(format!("{} trailing bytes after wire message", br.remaining()));
    }
    Ok((dst, msg))
}

// ---------------------------------------------------------------------------
// Varints and delta-encoded walks
// ---------------------------------------------------------------------------

/// LEB128 unsigned varint.
pub fn write_varint(mut x: u64, out: &mut Vec<u8>) {
    loop {
        let b = (x & 0x7f) as u8;
        x >>= 7;
        if x == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

pub fn read_varint(r: &mut ByteReader<'_>) -> Result<u64, String> {
    let mut x = 0u64;
    let mut shift = 0u32;
    loop {
        let b = r.u8()?;
        if shift >= 64 {
            return Err("varint longer than 64 bits".into());
        }
        x |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(x);
        }
        shift += 7;
    }
}

fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(z: u64) -> i64 {
    ((z >> 1) as i64) ^ -((z & 1) as i64)
}

/// Delta-encode a walk against its seed vertex: `len` then zigzag-varint
/// deltas between consecutive vertices (the first delta is against `vid`,
/// which is 0 for the walks FN produces — walks start at their seed).
pub fn encode_walk_delta(vid: VertexId, walk: &[VertexId], out: &mut Vec<u8>) {
    write_varint(walk.len() as u64, out);
    let mut prev = vid as i64;
    for &v in walk {
        write_varint(zigzag(v as i64 - prev), out);
        prev = v as i64;
    }
}

pub fn decode_walk_delta(vid: VertexId, r: &mut ByteReader<'_>) -> Result<Vec<VertexId>, String> {
    let len = read_varint(r)? as usize;
    let mut walk = Vec::with_capacity(len);
    let mut prev = vid as i64;
    for _ in 0..len {
        let v = prev + unzigzag(read_varint(r)?);
        if !(0..=u32::MAX as i64).contains(&v) {
            return Err(format!("delta-decoded vertex {v} out of u32 range"));
        }
        walk.push(v as VertexId);
        prev = v;
    }
    Ok(walk)
}

// ---------------------------------------------------------------------------
// Barrier reports and decisions
// ---------------------------------------------------------------------------

/// One shard's end-of-superstep accounting, sent in a `Barrier` frame.
/// Message counts/bytes are split by *process* locality: `within` stayed
/// inside the shard (any worker), `cross` crossed the transport.
/// `bytes_cross_sim` is the simulated (`wire_bytes`) size the aggregate
/// memory budget charges — identical to in-process accounting — while
/// `bytes_cross_wire` is the measured encoded payload the metrics report.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardReport {
    pub superstep: u32,
    pub active: u64,
    pub not_halted: u64,
    pub msgs_within: u64,
    pub msgs_cross: u64,
    pub bytes_within: u64,
    pub bytes_cross_sim: u64,
    pub bytes_cross_wire: u64,
    pub cache_bytes: u64,
    pub value_bytes: u64,
    pub hot_tasks: u64,
    /// Per local worker, in global worker order.
    pub compute_nanos: Vec<u64>,
    pub msgs_handled: Vec<u64>,
}

impl ShardReport {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(96 + 16 * self.compute_nanos.len());
        out.extend_from_slice(&self.superstep.to_le_bytes());
        for v in [
            self.active,
            self.not_halted,
            self.msgs_within,
            self.msgs_cross,
            self.bytes_within,
            self.bytes_cross_sim,
            self.bytes_cross_wire,
            self.cache_bytes,
            self.value_bytes,
            self.hot_tasks,
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&(self.compute_nanos.len() as u32).to_le_bytes());
        for v in &self.compute_nanos {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for v in &self.msgs_handled {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<ShardReport, String> {
        let mut r = ByteReader::new(buf);
        let superstep = r.u32()?;
        let mut fields = [0u64; 10];
        for f in &mut fields {
            *f = r.u64()?;
        }
        let workers = r.u32()? as usize;
        let mut compute_nanos = Vec::with_capacity(workers);
        for _ in 0..workers {
            compute_nanos.push(r.u64()?);
        }
        let mut msgs_handled = Vec::with_capacity(workers);
        for _ in 0..workers {
            msgs_handled.push(r.u64()?);
        }
        if !r.is_empty() {
            return Err(format!("{} trailing bytes after shard report", r.remaining()));
        }
        Ok(ShardReport {
            superstep,
            active: fields[0],
            not_halted: fields[1],
            msgs_within: fields[2],
            msgs_cross: fields[3],
            bytes_within: fields[4],
            bytes_cross_sim: fields[5],
            bytes_cross_wire: fields[6],
            cache_bytes: fields[7],
            value_bytes: fields[8],
            hot_tasks: fields[9],
            compute_nanos,
            msgs_handled,
        })
    }
}

/// The coordinator's verdict for one superstep barrier, broadcast in a
/// `Decision` frame. Mirrors the in-process leader's decision order: OOM,
/// then quiescence, then the superstep cap, then checkpoint cadence.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Keep going; `checkpoint` asks shards to enter the checkpoint phase.
    Continue { checkpoint: bool },
    /// All shards quiesced: send `Values` and await the next `Run`.
    Stop,
    /// Aggregate memory budget exceeded.
    StopOom { superstep: u32, bytes: u64 },
    /// Superstep cap reached.
    StopCap { supersteps: u32 },
    /// A peer shard (or the coordinator) failed; abandon the unit.
    Abort { detail: String },
}

impl Decision {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Decision::Continue { checkpoint } => {
                out.push(0);
                out.push(u8::from(*checkpoint));
            }
            Decision::Stop => out.push(1),
            Decision::StopOom { superstep, bytes } => {
                out.push(2);
                out.extend_from_slice(&superstep.to_le_bytes());
                out.extend_from_slice(&bytes.to_le_bytes());
            }
            Decision::StopCap { supersteps } => {
                out.push(3);
                out.extend_from_slice(&supersteps.to_le_bytes());
            }
            Decision::Abort { detail } => {
                out.push(4);
                out.extend_from_slice(&(detail.len() as u32).to_le_bytes());
                out.extend_from_slice(detail.as_bytes());
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Decision, String> {
        let mut r = ByteReader::new(buf);
        let d = match r.u8()? {
            0 => Decision::Continue {
                checkpoint: r.u8()? != 0,
            },
            1 => Decision::Stop,
            2 => Decision::StopOom {
                superstep: r.u32()?,
                bytes: r.u64()?,
            },
            3 => Decision::StopCap {
                supersteps: r.u32()?,
            },
            4 => {
                let len = r.u32()? as usize;
                let detail = String::from_utf8_lossy(r.take(len)?).into_owned();
                Decision::Abort { detail }
            }
            other => return Err(format!("unknown decision tag {other}")),
        };
        if !r.is_empty() {
            return Err(format!("{} trailing bytes after decision", r.remaining()));
        }
        Ok(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame() -> Frame {
        Frame::new(FrameKind::Data, 1, 2, 7, vec![9, 8, 7, 6, 5])
    }

    #[test]
    fn frame_roundtrips_through_codec() {
        let f = frame();
        let bytes = encode_frame(&f);
        assert_eq!(bytes.len(), FRAME_HEADER_BYTES + 5);
        assert_eq!(decode_frame(&bytes).unwrap(), f);

        // Empty payloads are legal (e.g. Shutdown).
        let empty = Frame::new(FrameKind::Shutdown, COORD_ID, 0, 0, vec![]);
        assert_eq!(decode_frame(&encode_frame(&empty)).unwrap(), empty);
    }

    #[test]
    fn corrupt_frames_fail_typed() {
        let good = encode_frame(&frame());

        // Bad magic.
        let mut b = good.clone();
        b[0] ^= 0xff;
        assert!(matches!(decode_frame(&b), Err(FrameError::BadMagic { .. })));

        // Unknown kind tag.
        let mut b = good.clone();
        b[4] = 200;
        assert_eq!(decode_frame(&b), Err(FrameError::BadKind { got: 200 }));

        // Oversized length field.
        let mut b = good.clone();
        b[12..16].copy_from_slice(&(MAX_FRAME_BYTES + 1).to_le_bytes());
        assert!(matches!(decode_frame(&b), Err(FrameError::TooLarge { .. })));

        // Length pointing past the buffer.
        let mut b = good.clone();
        b[12..16].copy_from_slice(&100u32.to_le_bytes());
        assert!(matches!(decode_frame(&b), Err(FrameError::Truncated { .. })));

        // Flipped payload byte fails the checksum.
        let mut b = good.clone();
        *b.last_mut().unwrap() ^= 1;
        assert!(matches!(decode_frame(&b), Err(FrameError::BadChecksum { .. })));

        // Truncation at every prefix is typed, never a panic.
        for cut in 0..good.len() {
            match decode_frame(&good[..cut]) {
                Err(FrameError::Truncated { .. }) | Err(FrameError::BadMagic { .. }) => {}
                other => panic!("cut at {cut}: unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn chan_transport_delivers_in_order_and_closes() {
        let (mut a, mut b) = ChanTransport::pair();
        let f1 = frame();
        let f2 = Frame::new(FrameKind::Barrier, 0, COORD_ID, 3, vec![1]);
        a.send(&f1).unwrap();
        a.send(&f2).unwrap();
        assert_eq!(b.recv().unwrap(), f1);
        assert_eq!(b.recv().unwrap(), f2);
        drop(a);
        assert_eq!(b.recv(), Err(FrameError::Closed));
    }

    #[test]
    fn chan_transport_split_halves_keep_working() {
        let (a, mut b) = ChanTransport::pair();
        let (mut rd, mut wr) = (Box::new(a) as Box<dyn Transport>).split().unwrap();
        wr.send(&frame()).unwrap();
        assert_eq!(b.recv().unwrap(), frame());
        b.send(&frame()).unwrap();
        assert_eq!(rd.recv().unwrap(), frame());
        // The wrong half is a typed close, not a hang.
        assert_eq!(rd.send(&frame()), Err(FrameError::Closed));
        assert_eq!(wr.recv(), Err(FrameError::Closed));
    }

    #[test]
    fn uds_transport_roundtrips_and_reports_truncation() {
        let (s1, s2) = UnixStream::pair().unwrap();
        let mut a = UdsTransport::new(s1);
        let mut b = UdsTransport::new(s2);
        let f = frame();
        a.send(&f).unwrap();
        assert_eq!(b.recv().unwrap(), f);

        // Clean close at a frame boundary.
        let (s1, s2) = UnixStream::pair().unwrap();
        drop(UdsTransport::new(s1));
        assert_eq!(UdsTransport::new(s2).recv(), Err(FrameError::Closed));

        // Close mid-frame is a truncation, not a clean close.
        let (s1, s2) = UnixStream::pair().unwrap();
        let mut raw = s1;
        let bytes = encode_frame(&f);
        raw.write_all(&bytes[..10]).unwrap();
        drop(raw);
        assert!(matches!(
            UdsTransport::new(s2).recv(),
            Err(FrameError::Truncated { .. })
        ));
    }

    #[test]
    fn varint_and_zigzag_roundtrip() {
        let mut out = Vec::new();
        let cases = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        for &c in &cases {
            out.clear();
            write_varint(c, &mut out);
            let mut r = ByteReader::new(&out);
            assert_eq!(read_varint(&mut r).unwrap(), c);
            assert!(r.is_empty());
        }
        for v in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }

    #[test]
    fn walk_delta_roundtrips_and_compresses_local_walks() {
        let walk: Vec<u32> = vec![5, 6, 5, 9, 8, 8, 200, 199];
        let mut out = Vec::new();
        encode_walk_delta(5, &walk, &mut out);
        // Seven of eight hops are small deltas: one byte each.
        assert!(out.len() < walk.len() * 4, "no compression: {}", out.len());
        let mut r = ByteReader::new(&out);
        assert_eq!(decode_walk_delta(5, &mut r).unwrap(), walk);
        assert!(r.is_empty());

        let empty: Vec<u32> = vec![];
        out.clear();
        encode_walk_delta(3, &empty, &mut out);
        let mut r = ByteReader::new(&out);
        assert_eq!(decode_walk_delta(3, &mut r).unwrap(), empty);
    }

    #[test]
    fn shard_report_roundtrips() {
        let rep = ShardReport {
            superstep: 4,
            active: 10,
            not_halted: 3,
            msgs_within: 100,
            msgs_cross: 7,
            bytes_within: 1200,
            bytes_cross_sim: 84,
            bytes_cross_wire: 140,
            cache_bytes: 64,
            value_bytes: 4096,
            hot_tasks: 2,
            compute_nanos: vec![11, 22, 33],
            msgs_handled: vec![5, 6, 7],
        };
        assert_eq!(ShardReport::decode(&rep.encode()).unwrap(), rep);
        assert!(ShardReport::decode(&rep.encode()[..10]).is_err());
    }

    #[test]
    fn sequence_numbers_stamp_and_verify_per_direction() {
        let (mut a, mut b) = ChanTransport::pair();
        // Outgoing frames are stamped 0, 1, 2, ...
        for expect in 0u8..3 {
            let bytes = a.encode_outgoing(&frame());
            assert_eq!(bytes[7], expect);
            a.send_bytes(bytes).unwrap();
            assert_eq!(b.recv().unwrap(), frame());
        }
        // The opposite direction counts independently.
        let bytes = b.encode_outgoing(&frame());
        assert_eq!(bytes[7], 0);
    }

    #[test]
    fn dropped_frame_surfaces_as_bad_seq() {
        let (mut a, mut b) = ChanTransport::pair();
        // Encode (consuming seq 0) but never send: a silent drop.
        let _lost = a.encode_outgoing(&frame());
        a.send(&frame()).unwrap();
        assert_eq!(b.recv(), Err(FrameError::BadSeq { expected: 0, got: 1 }));
    }

    #[test]
    fn duplicated_frame_surfaces_as_bad_seq() {
        let (mut a, mut b) = ChanTransport::pair();
        let bytes = a.encode_outgoing(&frame());
        a.send_bytes(bytes.clone()).unwrap();
        a.send_bytes(bytes).unwrap();
        assert_eq!(b.recv().unwrap(), frame());
        assert_eq!(b.recv(), Err(FrameError::BadSeq { expected: 1, got: 0 }));
    }

    #[test]
    fn uds_transport_verifies_sequence_numbers() {
        let (s1, s2) = UnixStream::pair().unwrap();
        let mut a = UdsTransport::new(s1);
        let mut b = UdsTransport::new(s2);
        // A duplicate on the socket: stamp seq 0 twice.
        let bytes = a.encode_outgoing(&frame());
        a.send_bytes(bytes.clone()).unwrap();
        a.send_bytes(bytes).unwrap();
        assert_eq!(b.recv().unwrap(), frame());
        assert_eq!(b.recv(), Err(FrameError::BadSeq { expected: 1, got: 0 }));
    }

    #[test]
    fn split_halves_continue_the_sequence_streams() {
        let (mut a, b) = ChanTransport::pair();
        let mut b: Box<dyn Transport> = Box::new(b);
        a.send(&frame()).unwrap();
        assert_eq!(b.recv().unwrap(), frame());
        let (mut rd, mut wr) = b.split().unwrap();
        // Writer half continues tx at 0 (b never sent); reader half
        // expects a's next frame to carry seq 1.
        a.send(&frame()).unwrap();
        assert_eq!(rd.recv().unwrap(), frame());
        wr.send(&frame()).unwrap();
        assert_eq!(a.recv().unwrap(), frame());
    }

    #[test]
    fn heartbeat_kind_roundtrips() {
        assert_eq!(FrameKind::from_u8(11), Some(FrameKind::Heartbeat));
        let hb = Frame::new(FrameKind::Heartbeat, 3, COORD_ID, 0, vec![]);
        assert_eq!(decode_frame(&encode_frame(&hb)).unwrap(), hb);
    }

    fn chaos_pair(cfg: ChaosConfig) -> (Box<dyn Transport>, ChanTransport) {
        let (a, b) = ChanTransport::pair();
        let wrapped = ChaosTransport::wrap(Box::new(a), cfg, 0, CHAOS_DIR_TO_COORD, 0);
        (wrapped, b)
    }

    #[test]
    fn chaos_drop_leaves_a_detectable_sequence_hole() {
        let mut cfg = ChaosConfig::new(7);
        cfg.drop_pm = 1000;
        let (mut a, mut b) = chaos_pair(cfg);
        a.send(&frame()).unwrap(); // dropped, seq 0 consumed
        // Hello frames are exempt from chaos and reveal the hole.
        let hello = Frame::new(FrameKind::Hello, 0, COORD_ID, 0, vec![1]);
        a.send(&hello).unwrap();
        assert_eq!(b.recv(), Err(FrameError::BadSeq { expected: 0, got: 1 }));
    }

    #[test]
    fn chaos_dup_flip_trunc_and_delay_are_typed_or_benign() {
        // Duplicate: second copy replays a stale sequence number.
        let mut cfg = ChaosConfig::new(7);
        cfg.dup_pm = 1000;
        let (mut a, mut b) = chaos_pair(cfg);
        a.send(&frame()).unwrap();
        assert_eq!(b.recv().unwrap(), frame());
        assert_eq!(b.recv(), Err(FrameError::BadSeq { expected: 1, got: 0 }));

        // Flip: restricted to payload bytes, always a checksum failure.
        let mut cfg = ChaosConfig::new(7);
        cfg.flip_pm = 1000;
        let (mut a, mut b) = chaos_pair(cfg);
        a.send(&frame()).unwrap();
        assert!(matches!(b.recv(), Err(FrameError::BadChecksum { .. })));

        // Truncation: typed, never a panic.
        let mut cfg = ChaosConfig::new(7);
        cfg.trunc_pm = 1000;
        let (mut a, mut b) = chaos_pair(cfg);
        a.send(&frame()).unwrap();
        assert!(matches!(b.recv(), Err(FrameError::Truncated { .. })));

        // Delay: benign, the frame still arrives intact and in sequence.
        let mut cfg = ChaosConfig::new(7);
        cfg.delay_pm = 1000;
        cfg.delay_ms = 1;
        let (mut a, mut b) = chaos_pair(cfg);
        a.send(&frame()).unwrap();
        assert_eq!(b.recv().unwrap(), frame());
    }

    #[test]
    fn chaos_flip_data_nth_corrupts_exactly_one_data_frame() {
        let cfg = ChaosConfig::new(7).with_flip_data_nth(1);
        let (mut a, mut b) = chaos_pair(cfg);
        a.send(&frame()).unwrap();
        assert_eq!(b.recv().unwrap(), frame());
        a.send(&frame()).unwrap(); // the 2nd Data frame: flipped
        assert!(matches!(b.recv(), Err(FrameError::BadChecksum { .. })));
        // A frame error poisons the stream: the corrupted frame never
        // advanced the receive counter, so the connection must be torn
        // down (which is exactly what the coordinator does).
        let barrier = Frame::new(FrameKind::Barrier, 0, COORD_ID, 0, vec![2]);
        a.send(&barrier).unwrap();
        assert_eq!(b.recv(), Err(FrameError::BadSeq { expected: 1, got: 2 }));
    }

    #[test]
    fn chaos_schedule_is_deterministic_per_seed() {
        let schedule = |seed: u64| -> Vec<Mutation> {
            let t = ChaosTransport {
                inner: Box::new(ChanTransport::pair().0),
                cfg: ChaosConfig::light(seed),
                shard: 1,
                dir: CHAOS_DIR_TO_COORD,
                generation: 0,
                sent: 0,
                data_sent: 0,
            };
            (0..512).map(|i| t.mutation_for(i)).collect()
        };
        assert_eq!(schedule(42), schedule(42));
        assert_ne!(schedule(42), schedule(43), "seeds share a schedule");
        // The light preset actually fires within a few hundred frames.
        assert!(
            schedule(42).iter().any(|m| *m != Mutation::Pass),
            "light chaos never fired in 512 frames"
        );
    }

    #[test]
    fn decision_roundtrips() {
        for d in [
            Decision::Continue { checkpoint: false },
            Decision::Continue { checkpoint: true },
            Decision::Stop,
            Decision::StopOom {
                superstep: 9,
                bytes: 1 << 40,
            },
            Decision::StopCap { supersteps: 10_000 },
            Decision::Abort {
                detail: "shard 2 died".into(),
            },
        ] {
            assert_eq!(Decision::decode(&d.encode()).unwrap(), d);
        }
        assert!(Decision::decode(&[99]).is_err());
    }
}
