//! The BSP engine: master/worker supersteps over an immutable [`Graph`].
//!
//! Execution model (mirrors GraphLite, Figure 3 of the paper):
//! - the graph is partitioned across `W` workers before the run;
//! - the master starts a superstep; every worker invokes `compute` for each
//!   of its *active* vertices (received messages or not halted);
//! - `compute` reads the incoming message list, updates the vertex value in
//!   place, and sends messages to be delivered next superstep;
//! - the master waits for all workers (global barrier), aggregates metrics,
//!   checks termination (all halted, no messages in flight) and the memory
//!   budget, then starts the next superstep.
//!
//! Workers are threads; the master role is played by the barrier leader.
//! All sampling determinism is the program's responsibility (derive RNG
//! streams from `(seed, walk, superstep)`), so results are independent of
//! worker count — a property the test suite checks.
//!
//! # Hot-vertex splitting
//!
//! On power-law graphs one hub can receive more messages than the rest of
//! its worker's partition combined; the barrier then makes every superstep
//! as slow as that worker. When [`EngineOpts::hot_degree_threshold`] is
//! set, messages delivered to a vertex whose degree reaches the threshold
//! are sharded: the owner keeps the messages that need the vertex's
//! persistent value (the program classifies them via
//! [`VertexProgram::splittable`]) and pushes the rest to a shared hot
//! queue in fixed-size chunks; after a barrier, *all* workers drain the
//! queue work-stealing style, executing each chunk with the program's
//! `compute` under a context that impersonates the owner (`my_worker()`
//! reports the owner, so partition-relative decisions are unchanged) and a
//! fresh default value. Programs opting in must therefore tolerate
//! (a) `compute` seeing any subset of a hot vertex's messages and
//! (b) split chunks running with a default value on another worker's
//! cache — the FN protocol does (worst case a cache miss retries).
//! Results stay bit-identical because sampling draws only from
//! per-(walk, step) RNG streams; only *where* a message is processed
//! changes, which the per-worker compute-time metrics make visible.

use std::panic::AssertUnwindSafe;
use std::time::Instant;

use crate::util::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use crate::util::sync::barrier::{BarrierWait, PoisonBarrier};
use crate::util::sync::{thread, Arc, Condvar, Mutex};

use crate::graph::partition::Partitioner;
use crate::graph::{Graph, VertexId};
use crate::util::fxhash::FxHashMap;

use super::checkpoint::{self, ByteReader, CheckpointSpec, EncodedPart, EngineSnapshot, Persist};
use super::metrics::{EngineMetrics, SuperstepMetrics};
use super::transport::{self, Decision, Frame, FrameKind, ShardReport, Transport, WireMsg, COORD_ID};
use super::Message;

/// A vertex-centric program.
pub trait VertexProgram: Sync {
    /// Per-vertex mutable state (updated in place — the Pregel advantage
    /// over Spark's copy-on-write RDDs that the paper leans on).
    type Value: Send + Default;
    /// Message type; must report wire size for the network accounting.
    type Msg: Message;

    /// Initial value for vertex `vid`.
    fn init_value(&self, _vid: VertexId) -> Self::Value {
        Default::default()
    }

    /// The compute function, run once per active vertex per superstep.
    /// `msgs` are the messages delivered this superstep (sent last one).
    fn compute(
        &self,
        ctx: &mut Ctx<'_, Self>,
        vid: VertexId,
        value: &mut Self::Value,
        msgs: &mut Vec<Self::Msg>,
    );

    /// Hot-vertex splitting capability probe: programs that never return
    /// `true` from [`VertexProgram::splittable`] keep the default `false`
    /// here, and the engine skips the whole splitting machinery for them
    /// (including its extra per-superstep barrier and the per-message
    /// classification scan at hot vertices).
    fn supports_hot_split(&self) -> bool {
        false
    }

    /// Hot-vertex splitting opt-in (see the module doc). Return `true`
    /// when `msg` can be processed for its destination vertex by *any*
    /// worker via a `compute` call that receives a fresh
    /// `Self::Value::default()` — i.e. handling the message must not
    /// depend on, or durably mutate, the vertex's persistent value, and
    /// must be independent of which other messages accompany it.
    ///
    /// Only consulted when [`VertexProgram::supports_hot_split`] is
    /// `true`; override both together.
    fn splittable(&self, _msg: &Self::Msg) -> bool {
        false
    }

    /// Approximate resident bytes of a value (base-usage accounting).
    fn value_bytes(&self, _v: &Self::Value) -> u64 {
        8
    }
}

/// Engine options.
#[derive(Clone, Copy, Debug)]
pub struct EngineOpts {
    /// Hard stop after this many supersteps (safety net; walk programs
    /// terminate themselves by voting to halt).
    pub max_supersteps: u32,
    /// Simulated aggregate memory budget. Exceeding it aborts the run with
    /// [`EngineError::OutOfMemory`] — used to reproduce the paper's OOM
    /// markers ("x" in Figure 7) and FN-Multi's motivation.
    pub memory_budget: Option<u64>,
    /// Per-worker adjacency cache capacity in bytes (FN-Cache). `None`
    /// disables capacity checks.
    pub cache_capacity: Option<u64>,
    /// Hot-vertex splitting: vertices whose degree is at least this get
    /// their splittable incoming messages sharded across workers within a
    /// superstep (work stealing over a shared hot queue; see the module
    /// doc). `None` disables splitting. Programs that don't opt in via
    /// [`VertexProgram::supports_hot_split`] are entirely unaffected —
    /// the engine doesn't even take the extra barrier for them.
    pub hot_degree_threshold: Option<u32>,
    /// Memory-budget policy for the *session driver*: the engine itself
    /// always reports an overrun as [`EngineError::OutOfMemory`], but a
    /// walk session degrades gracefully (splits the round into smaller
    /// FN-Multi classes and retries) unless this is `true`, in which case
    /// the overrun aborts the query — the pre-degradation behavior.
    pub strict_memory: bool,
    /// Request hot-vertex chunks be stolen *across shard processes* in a
    /// distributed run. The hot queue is a shared-memory structure that
    /// cannot cross a process boundary, so this is not implemented: asking
    /// for it with more than one shard yields [`EngineError::Config`]
    /// instead of silently dropping chunks. In-process runs ignore the
    /// flag (every worker already shares one queue).
    pub hot_split_cross_shard: bool,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts {
            max_supersteps: 10_000,
            memory_budget: None,
            cache_capacity: None,
            hot_degree_threshold: None,
            strict_memory: false,
            hot_split_cross_shard: false,
        }
    }
}

/// Don't bother splitting a hot vertex with fewer delivered messages than
/// this: the queue round-trip would cost more than the compute.
const HOT_MIN_SPLIT_MSGS: usize = 32;

/// Lower bound on chunk size handed to the hot queue.
const HOT_MIN_CHUNK: usize = 16;

/// A chunk of one hot vertex's messages, executable by any worker on the
/// owner's behalf.
struct HotTask<M> {
    vid: VertexId,
    owner: usize,
    msgs: Vec<M>,
}

/// Run failure modes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// Simulated cluster memory exhausted (paper Figure 7 "x" marks).
    OutOfMemory { superstep: u32, bytes: u64 },
    /// `max_supersteps` reached without quiescence.
    DidNotTerminate { supersteps: u32 },
    /// A worker thread panicked. The panic is caught at the thread
    /// boundary, the barrier is poisoned so siblings drain cleanly, and
    /// the payload is carried here instead of aborting the process.
    WorkerFailed {
        worker: usize,
        superstep: u32,
        payload: String,
    },
    /// Writing a superstep checkpoint failed persistently (after the
    /// transient-IO retries); no partial file is left behind.
    Checkpoint { superstep: u32, detail: String },
    /// The requested run configuration is invalid (e.g. cross-shard hot
    /// splitting, which shared-memory work stealing cannot provide).
    Config { detail: String },
    /// A shard process failed or its transport broke mid-run; the
    /// coordinator aborts the unit and surfaces the first failure.
    /// `shard == usize::MAX` means the failure was on the coordinator
    /// side (launch, accept, or frame forwarding).
    ShardFailed { shard: usize, detail: String },
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::OutOfMemory { superstep, bytes } => write!(
                f,
                "simulated OOM at superstep {superstep}: {} exceeds budget",
                crate::util::fmt_bytes(*bytes)
            ),
            EngineError::DidNotTerminate { supersteps } => {
                write!(f, "no quiescence after {supersteps} supersteps")
            }
            EngineError::WorkerFailed {
                worker,
                superstep,
                payload,
            } => write!(
                f,
                "worker {worker} failed at superstep {superstep}: {payload}"
            ),
            EngineError::Checkpoint { superstep, detail } => {
                write!(f, "checkpoint at superstep {superstep} failed: {detail}")
            }
            EngineError::Config { detail } => write!(f, "invalid configuration: {detail}"),
            EngineError::ShardFailed { shard, detail } => {
                if *shard == usize::MAX {
                    write!(f, "coordinator failed: {detail}")
                } else {
                    write!(f, "shard {shard} failed: {detail}")
                }
            }
        }
    }
}

impl std::error::Error for EngineError {}

/// Successful run output.
pub struct RunResult<V> {
    /// Final vertex values indexed by vertex id.
    pub values: Vec<V>,
    pub metrics: EngineMetrics,
}

/// Precomputed per-worker vertex lists for one (partitioner, graph) pair —
/// the engine state worth keeping between runs.
///
/// [`Engine::run`] derives this from the partitioner on every call (an
/// O(n) scan); a prepared caller (e.g. a
/// [`WalkSession`](crate::node2vec::WalkSession)) builds the plan once and
/// replays many runs through [`Engine::run_on`], so per-query engine setup
/// is just value/inbox allocation instead of a full re-partition scan.
pub struct WorkerPlan {
    per_worker: Vec<Vec<VertexId>>,
}

impl WorkerPlan {
    /// Bucket `0..num_vertices` by owning worker in one pass (each bucket
    /// stays in ascending id order, matching `Partitioner::vertices_of`).
    pub fn new(part: &Partitioner, num_vertices: usize) -> WorkerPlan {
        let mut per_worker: Vec<Vec<VertexId>> = (0..part.num_workers())
            .map(|_| Vec::new())
            .collect();
        for v in 0..num_vertices as VertexId {
            per_worker[part.worker_of(v)].push(v);
        }
        WorkerPlan { per_worker }
    }

    #[inline]
    pub fn num_workers(&self) -> usize {
        self.per_worker.len()
    }

    /// Vertices owned by `worker`, in ascending id order.
    #[inline]
    pub fn vertices(&self, worker: usize) -> &[VertexId] {
        &self.per_worker[worker]
    }
}

/// Per-worker adjacency cache (FN-Cache's global per-worker structure).
/// Keyed by vertex id with FxHash: the keys are graph-derived (not
/// adversarial), and every Marker hop costs one lookup here, so the
/// SipHash hardening of std's default hasher is wasted work
/// (see EXPERIMENTS.md §Perf).
struct WorkerCache {
    map: FxHashMap<VertexId, Arc<[VertexId]>>,
    bytes: u64,
    capacity: Option<u64>,
}

impl WorkerCache {
    fn new(capacity: Option<u64>) -> Self {
        WorkerCache {
            map: FxHashMap::default(),
            bytes: 0,
            capacity,
        }
    }

    fn get(&self, v: VertexId) -> Option<Arc<[VertexId]>> {
        self.map.get(&v).cloned()
    }

    fn put(&mut self, v: VertexId, neigh: Arc<[VertexId]>) -> bool {
        let sz = (neigh.len() * 4 + 16) as u64;
        if let Some(cap) = self.capacity {
            if self.bytes + sz > cap {
                return false; // full: no eviction (paper: cache benefit
                              // limited when memory is tight)
            }
        }
        if self.map.insert(v, neigh).is_none() {
            self.bytes += sz;
        }
        true
    }
}

/// Per-worker, per-superstep accumulators (merged into atomics at barrier).
#[derive(Default)]
struct LocalCounters {
    msgs_local: u64,
    msgs_remote: u64,
    bytes_local: u64,
    bytes_remote: u64,
    active: u64,
    /// Messages this worker processed (own vertices + stolen hot chunks).
    msgs_handled: u64,
}

/// The compute context handed to [`VertexProgram::compute`].
pub struct Ctx<'a, P: VertexProgram + ?Sized> {
    superstep: u32,
    graph: &'a Graph,
    part: &'a Partitioner,
    /// Worker the current compute runs *as*: for stolen hot chunks this is
    /// the vertex's owner, not the executing thread (see the module doc).
    me: usize,
    /// The physical executing worker (whose cache and out-buffers this
    /// context touches); equals `me` outside stolen hot chunks.
    executor: usize,
    /// True while processing a stolen hot-vertex chunk (ephemeral value).
    hot_chunk: bool,
    cur_vid: VertexId,
    halt: bool,
    out: &'a mut [Vec<(VertexId, P::Msg)>],
    counters: &'a mut LocalCounters,
    cache: &'a mut WorkerCache,
}

impl<'a, P: VertexProgram + ?Sized> Ctx<'a, P> {
    #[inline]
    pub fn superstep(&self) -> u32 {
        self.superstep
    }

    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.graph.num_vertices()
    }

    /// This worker's id (0-based).
    #[inline]
    pub fn my_worker(&self) -> usize {
        self.me
    }

    #[inline]
    pub fn num_workers(&self) -> usize {
        self.part.num_workers()
    }

    /// Id of the vertex whose `compute` is currently running.
    #[inline]
    pub fn current_vertex(&self) -> VertexId {
        self.cur_vid
    }

    /// Out-neighbors of the *current* vertex (its own out-edge array).
    #[inline]
    pub fn neighbors(&self) -> &'a [VertexId] {
        self.graph.neighbors(self.cur_vid)
    }

    /// Edge weights of the current vertex.
    #[inline]
    pub fn weights(&self) -> &'a [f32] {
        self.graph.weights(self.cur_vid)
    }

    #[inline]
    pub fn degree_of_self(&self) -> usize {
        self.graph.degree(self.cur_vid)
    }

    /// Worker owning `v` — the lookup API the paper adds for FN-Cache.
    #[inline]
    pub fn worker_of(&self, v: VertexId) -> usize {
        self.part.worker_of(v)
    }

    /// True while compute is processing a stolen hot-vertex chunk (see the
    /// module doc): the value is ephemeral, so programs should make
    /// state-free protocol choices on this path.
    #[inline]
    pub fn is_hot_chunk(&self) -> bool {
        self.hot_chunk
    }

    /// The physical worker whose cache [`Ctx::cache_get`] /
    /// [`Ctx::cache_put`] touch. Equals [`Ctx::my_worker`] except inside a
    /// stolen hot chunk, where `my_worker` impersonates the vertex's
    /// owner; cache-locality decisions must use this id.
    #[inline]
    pub fn cache_worker(&self) -> usize {
        self.executor
    }

    /// FN-Local's API: adjacency of another vertex **iff it lives in this
    /// worker's partition**; `None` for remote vertices (which must send
    /// their adjacency in a NEIG message instead).
    #[inline]
    pub fn local_neighbors(&self, v: VertexId) -> Option<(&'a [VertexId], &'a [f32])> {
        if self.part.worker_of(v) == self.me {
            Some((self.graph.neighbors(v), self.graph.weights(v)))
        } else {
            None
        }
    }

    /// Send `msg` to `dst`, delivered next superstep.
    ///
    /// Local/remote classification is relative to [`Ctx::my_worker`] —
    /// inside a stolen hot chunk that is the vertex's *owner*, i.e. the
    /// simulation models hot splitting as offloaded compute whose results
    /// are wired back through the owner (chunk shipment itself is charged
    /// zero bytes). Communication metrics measured with hot splitting
    /// enabled reflect that modeling choice.
    #[inline]
    pub fn send(&mut self, dst: VertexId, msg: P::Msg) {
        let w = self.part.worker_of(dst);
        let bytes = msg.wire_bytes();
        if w == self.me {
            self.counters.msgs_local += 1;
            self.counters.bytes_local += bytes;
        } else {
            self.counters.msgs_remote += 1;
            self.counters.bytes_remote += bytes;
        }
        self.out[w].push((dst, msg));
    }

    /// Vote to halt; reactivated by any incoming message.
    #[inline]
    pub fn vote_to_halt(&mut self) {
        self.halt = true;
    }

    /// FN-Cache: look up a remote vertex's adjacency in this worker's cache.
    #[inline]
    pub fn cache_get(&self, v: VertexId) -> Option<Arc<[VertexId]>> {
        self.cache.get(v)
    }

    /// FN-Cache: insert a remote vertex's adjacency. Returns `false` when
    /// the cache is at capacity (entry not inserted).
    #[inline]
    pub fn cache_put(&mut self, v: VertexId, neigh: Arc<[VertexId]>) -> bool {
        self.cache.put(v, neigh)
    }

    /// Bytes currently held by this worker's cache.
    #[inline]
    pub fn cache_bytes(&self) -> u64 {
        self.cache.bytes
    }
}

/// Checkpoint control shared by the workers of one checkpointed run.
struct CkptCtl<P: VertexProgram> {
    /// `Some` for in-process runs, which write the FN2VCKP1 file
    /// themselves; `None` for shard processes, which instead ship their
    /// encoded parts to the coordinator (the coordinator holds the spec
    /// and decides the cadence via [`Decision::Continue`]).
    spec: Option<CheckpointSpec>,
    /// Monomorphic encoders captured where the `Persist` bounds hold, so
    /// the shared worker loop needs no bounds of its own.
    persist_value: fn(&P::Value, &mut Vec<u8>),
    persist_msg: fn(&P::Msg, &mut Vec<u8>),
    /// Leader-set at the decision barrier: snapshot after this superstep.
    due: AtomicBool,
    /// Per-worker encoded state, collected between checkpoint barriers.
    parts: Mutex<Vec<Option<EncodedPart>>>,
    written: AtomicU64,
    nanos: AtomicU64,
}

/// Per-destination-shard outbound buffer: messages crossing the process
/// boundary are encoded with the real wire codec as workers flush, then
/// drained into one [`FrameKind::Data`] frame per destination by the
/// shard leader at the barrier.
#[derive(Default)]
struct OutBuf {
    bytes: Vec<u8>,
    msgs: u64,
    /// Self-reported `Msg::wire_bytes()` sum (the simulated accounting the
    /// paper's figures use — kept so budget decisions are bit-identical to
    /// the in-process engine).
    sim_bytes: u64,
    /// Measured encoded size (entry framing included) — what actually hits
    /// the transport, reported as `bytes_remote` by the coordinator.
    wire_bytes: u64,
}

/// Distributed-run control handed to [`worker_loop`] when this process is
/// one shard of a multi-process run. Workers `first..first + wps` of the
/// global worker space run here; everything else is remote. The shard
/// leader (barrier leader) speaks the coordinator protocol instead of
/// playing master itself.
pub(crate) struct RemoteCtl<'c, P: VertexProgram> {
    shard: usize,
    shards: usize,
    /// Workers per shard; global worker `w` lives on shard `w / wps`.
    wps: usize,
    /// First global worker index of this shard (`shard * wps`).
    first: usize,
    /// The duplex connection to the coordinator. Only the shard leader
    /// touches it during the exchange, but it must be shareable across
    /// the worker threads because any of them can be the leader.
    conn: &'c Mutex<Box<dyn Transport>>,
    /// One outbound buffer per destination shard (own slot unused).
    outbound: Vec<Mutex<OutBuf>>,
    /// Monomorphic wire codecs (same trick as [`CkptCtl`]'s persist fns).
    encode_entry: fn(VertexId, &P::Msg, &mut Vec<u8>) -> u64,
    decode_entry: fn(&mut ByteReader<'_>) -> Result<(VertexId, P::Msg), String>,
}

/// Shared state across worker threads for one run.
struct Shared<P: VertexProgram> {
    barrier: PoisonBarrier,
    /// Superstep currently in progress (workers race it upward at the top
    /// of each iteration; only read for failure reporting).
    cur_superstep: AtomicU32,
    /// Superstep checkpointing; `None` for plain runs (zero extra work).
    ckpt: Option<CkptCtl<P>>,
    /// Double-buffered inboxes, one per worker per superstep parity.
    /// Messages sent during superstep `s` land in `inboxes[(s+1) % 2]`
    /// while receivers drain `inboxes[s % 2]`, so a fast worker can never
    /// race its sends into an inbox that is still being drained.
    inboxes: [Vec<Mutex<Vec<(VertexId, P::Msg)>>>; 2],
    /// Hot-vertex chunks awaiting a worker (filled during the compute
    /// phase, drained work-stealing style after the hot barrier).
    hot_queue: Mutex<Vec<HotTask<P::Msg>>>,
    stop: AtomicBool,
    // Per-superstep accumulators (reset by the leader each step).
    msgs_local: AtomicU64,
    msgs_remote: AtomicU64,
    bytes_local: AtomicU64,
    bytes_remote: AtomicU64,
    active: AtomicU64,
    not_halted: AtomicU64,
    cache_bytes: AtomicU64,
    value_bytes: AtomicU64,
    hot_tasks: AtomicU64,
    /// Per-worker compute-phase nanoseconds / messages handled this
    /// superstep (each worker stores its own slot; leader reads all).
    worker_compute_nanos: Vec<AtomicU64>,
    worker_msgs: Vec<AtomicU64>,
    /// Leader-written, all-read after barrier.
    error: Mutex<Option<EngineError>>,
    metrics: Mutex<Vec<SuperstepMetrics>>,
    peak_bytes: AtomicU64,
}

/// The engine: a graph, a partitioner, a program, options.
pub struct Engine<'g, P: VertexProgram> {
    graph: &'g Graph,
    part: Partitioner,
    program: P,
    opts: EngineOpts,
}

impl<'g, P: VertexProgram> Engine<'g, P> {
    pub fn new(graph: &'g Graph, part: Partitioner, program: P, opts: EngineOpts) -> Self {
        Engine {
            graph,
            part,
            program,
            opts,
        }
    }

    pub fn program(&self) -> &P {
        &self.program
    }

    /// Execute to quiescence. Returns final vertex values and metrics.
    ///
    /// Derives the per-worker vertex lists from the partitioner first; a
    /// caller running many programs over the same (graph, partitioner)
    /// should build a [`WorkerPlan`] once and use [`Engine::run_on`].
    pub fn run(&self) -> Result<RunResult<P::Value>, EngineError> {
        let plan = WorkerPlan::new(&self.part, self.graph.num_vertices());
        self.run_on(&plan)
    }

    /// [`Engine::run`] against a prebuilt [`WorkerPlan`] (must have been
    /// built from this engine's partitioner over this graph's vertices).
    pub fn run_on(&self, plan: &WorkerPlan) -> Result<RunResult<P::Value>, EngineError> {
        self.run_inner(plan, None, None, None)
    }

    /// [`Engine::run_on`], writing an FN2VCKP1 checkpoint every
    /// `spec.every` supersteps (atomic temp-file + rename; see
    /// [`super::checkpoint`]). Results are identical to a plain run.
    pub fn run_on_checkpointed(
        &self,
        plan: &WorkerPlan,
        spec: &CheckpointSpec,
    ) -> Result<RunResult<P::Value>, EngineError>
    where
        P::Value: Persist,
        P::Msg: Persist,
    {
        self.run_inner(plan, None, Some(self.ckpt_ctl(plan, Some(spec))), None)
    }

    /// Restart from a checkpoint-reconstructed snapshot, optionally
    /// continuing to checkpoint. Messages are re-bucketed through this
    /// engine's partitioner, so resume works across worker counts and
    /// partitioning schemes; results are bit-identical to the
    /// uninterrupted run because sampling draws only from counter-based
    /// RNG streams, never from engine state.
    pub fn run_on_resumed(
        &self,
        plan: &WorkerPlan,
        snapshot: EngineSnapshot<P>,
        spec: Option<&CheckpointSpec>,
    ) -> Result<RunResult<P::Value>, EngineError>
    where
        P::Value: Persist,
        P::Msg: Persist,
    {
        let ckpt = spec.map(|s| self.ckpt_ctl(plan, Some(s)));
        self.run_inner(plan, Some(snapshot), ckpt, None)
    }

    /// Run this engine as shard `shard` of a `shards`-process distributed
    /// run, speaking the coordinator protocol over `conn`. The global
    /// worker space is `plan.num_workers()` wide; this process executes
    /// workers `shard * wps .. (shard + 1) * wps` and exchanges
    /// cross-shard messages through the coordinator as encoded
    /// [`FrameKind::Data`] frames. All master decisions (quiescence, OOM,
    /// superstep cap, checkpoint cadence) arrive as [`Decision`] frames;
    /// when `ckpt_active` the shard ships encoded checkpoint parts to the
    /// coordinator instead of writing files itself.
    pub fn run_sharded(
        &self,
        plan: &WorkerPlan,
        shard: usize,
        shards: usize,
        conn: &Mutex<Box<dyn Transport>>,
        ckpt_active: bool,
        resume: Option<EngineSnapshot<P>>,
    ) -> Result<RunResult<P::Value>, EngineError>
    where
        P::Value: Persist,
        P::Msg: Persist + WireMsg,
    {
        let w = self.part.num_workers();
        if shards == 0 || w % shards != 0 {
            return Err(EngineError::Config {
                detail: format!("{w} workers do not divide evenly into {shards} shards"),
            });
        }
        if self.opts.hot_split_cross_shard && shards > 1 {
            return Err(EngineError::Config {
                detail: "cross-shard hot splitting is not available: the hot queue is \
                         shared memory and cannot cross a process boundary"
                    .to_string(),
            });
        }
        let wps = w / shards;
        let rc = RemoteCtl::<P> {
            shard,
            shards,
            wps,
            first: shard * wps,
            conn,
            outbound: (0..shards).map(|_| Mutex::new(OutBuf::default())).collect(),
            encode_entry: transport::encode_entry::<P::Msg>,
            decode_entry: transport::decode_entry::<P::Msg>,
        };
        let ckpt = if ckpt_active {
            Some(self.ckpt_ctl(plan, None))
        } else {
            None
        };
        self.run_inner(plan, resume, ckpt, Some(&rc))
    }

    fn ckpt_ctl(&self, plan: &WorkerPlan, spec: Option<&CheckpointSpec>) -> CkptCtl<P>
    where
        P::Value: Persist,
        P::Msg: Persist,
    {
        CkptCtl {
            spec: spec.cloned(),
            persist_value: <P::Value as Persist>::persist,
            persist_msg: <P::Msg as Persist>::persist,
            due: AtomicBool::new(false),
            parts: Mutex::new((0..plan.num_workers()).map(|_| None).collect()),
            written: AtomicU64::new(0),
            nanos: AtomicU64::new(0),
        }
    }

    fn run_inner(
        &self,
        plan: &WorkerPlan,
        resume: Option<EngineSnapshot<P>>,
        ckpt: Option<CkptCtl<P>>,
        remote: Option<&RemoteCtl<'_, P>>,
    ) -> Result<RunResult<P::Value>, EngineError> {
        let w = self.part.num_workers();
        let n = self.graph.num_vertices();
        assert_eq!(
            plan.num_workers(),
            w,
            "worker plan built for a different worker count"
        );
        debug_assert_eq!(
            plan.per_worker.iter().map(Vec::len).sum::<usize>(),
            n,
            "worker plan built for a different graph"
        );
        let t_run = Instant::now();
        let io_retries_at_start = crate::util::failpoints::io_retries();
        let start_superstep = resume.as_ref().map_or(0, |s| s.superstep);

        // In a sharded run only this shard's workers exist as threads, so
        // the barrier synchronizes `wps` parties, not the global count.
        let local_workers: Vec<usize> = match remote {
            Some(rc) => (rc.first..rc.first + rc.wps).collect(),
            None => (0..w).collect(),
        };
        let shared: Shared<P> = Shared {
            barrier: PoisonBarrier::new(local_workers.len()),
            cur_superstep: AtomicU32::new(start_superstep),
            ckpt,
            inboxes: [
                (0..w).map(|_| Mutex::new(Vec::new())).collect(),
                (0..w).map(|_| Mutex::new(Vec::new())).collect(),
            ],
            hot_queue: Mutex::new(Vec::new()),
            stop: AtomicBool::new(false),
            msgs_local: AtomicU64::new(0),
            msgs_remote: AtomicU64::new(0),
            bytes_local: AtomicU64::new(0),
            bytes_remote: AtomicU64::new(0),
            active: AtomicU64::new(0),
            not_halted: AtomicU64::new(0),
            cache_bytes: AtomicU64::new(0),
            value_bytes: AtomicU64::new(0),
            hot_tasks: AtomicU64::new(0),
            worker_compute_nanos: (0..w).map(|_| AtomicU64::new(0)).collect(),
            worker_msgs: (0..w).map(|_| AtomicU64::new(0)).collect(),
            error: Mutex::new(None),
            metrics: Mutex::new(Vec::new()),
            peak_bytes: AtomicU64::new(0),
        };

        // Charge the graph as served, not just its topology: FN-Reject's
        // first-order alias tables are real resident state built before
        // the run, and a budget that ignored them let runs survive limits
        // they should OOM under (skewing the §Perf memory claims).
        let graph_bytes = self.graph.resident_bytes();
        let opts = self.opts;

        // Hand each worker its start state: superstep 0 with program-
        // initialized values for a fresh run, or the checkpoint-restored
        // slice of the snapshot for a resumed one. In-flight messages are
        // re-bucketed through *this* engine's partitioner, which is what
        // makes resume independent of the original worker layout.
        let starts: Vec<WorkerStart<P>> = match resume {
            Some(snap) => {
                let EngineSnapshot {
                    superstep,
                    values,
                    halted,
                    messages,
                } = snap;
                assert_eq!(values.len(), n, "snapshot built for a different graph");
                let parity = (superstep % 2) as usize;
                for (dst, msg) in messages {
                    let dw = self.part.worker_of(dst);
                    // Sharded resume: the snapshot is broadcast whole, each
                    // shard keeps only the messages its workers own.
                    if let Some(rc) = remote {
                        if dw / rc.wps != rc.shard {
                            continue;
                        }
                    }
                    shared.inboxes[parity][dw].lock().unwrap().push((dst, msg));
                }
                let mut dense = values;
                (0..w)
                    .map(|me| WorkerStart {
                        superstep,
                        values: Some(
                            plan.vertices(me)
                                .iter()
                                .map(|&v| std::mem::take(&mut dense[v as usize]))
                                .collect(),
                        ),
                        halted: Some(
                            plan.vertices(me)
                                .iter()
                                .map(|&v| halted[v as usize])
                                .collect(),
                        ),
                    })
                    .collect()
            }
            None => (0..w)
                .map(|_| WorkerStart {
                    superstep: 0,
                    values: None,
                    halted: None,
                })
                .collect(),
        };

        let worker_outputs: Vec<Vec<P::Value>> = thread::scope(|scope| {
            let shared = &shared;
            let mut handles = Vec::with_capacity(local_workers.len());
            for (me, start) in starts.into_iter().enumerate() {
                if !local_workers.contains(&me) {
                    continue;
                }
                let program = &self.program;
                let graph = self.graph;
                let part = &self.part;
                let my_vertices = plan.vertices(me);
                handles.push(scope.spawn(move || {
                    // A panic inside `compute` (or the engine itself) must
                    // not take the process down or deadlock the siblings:
                    // catch it, record a typed error, poison the barrier so
                    // every other worker drains out cleanly.
                    let run = std::panic::catch_unwind(AssertUnwindSafe(|| {
                        worker_loop::<P>(
                            me,
                            graph,
                            part,
                            my_vertices,
                            program,
                            shared,
                            opts,
                            graph_bytes,
                            start,
                            remote,
                        )
                    }));
                    run.unwrap_or_else(|payload| {
                        let superstep = shared.cur_superstep.load(Ordering::Relaxed);
                        let mut err =
                            shared.error.lock().unwrap_or_else(|p| p.into_inner());
                        if err.is_none() {
                            *err = Some(EngineError::WorkerFailed {
                                worker: me,
                                superstep,
                                payload: panic_payload(payload),
                            });
                        }
                        drop(err);
                        shared.stop.store(true, Ordering::Relaxed);
                        shared.barrier.poison();
                        Vec::new()
                    })
                }));
            }
            // The closure above never panics (worker_loop panics are caught
            // inside it), so a join error is impossible; default keeps the
            // error path allocation-free.
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_default())
                .collect()
        });

        if let Some(err) = shared
            .error
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .take()
        {
            return Err(err);
        }

        // Scatter worker-local values back to a dense vid-indexed vec (in
        // a sharded run only this shard's workers contributed; the rest of
        // the vec stays `Default` and the coordinator assembles the whole).
        let mut values: Vec<P::Value> = Vec::with_capacity(n);
        values.resize_with(n, Default::default);
        for (&me, vals) in local_workers.iter().zip(worker_outputs) {
            for (&vid, val) in plan.vertices(me).iter().zip(vals) {
                values[vid as usize] = val;
            }
        }

        let supersteps = std::mem::take(&mut *shared.metrics.lock().unwrap());
        // Base usage = topology + final vertex values (the per-step atomic
        // was reset by the leader, so recompute from the assembled values).
        let final_value_bytes: u64 = values.iter().map(|v| self.program.value_bytes(v)).sum();
        let base_bytes = graph_bytes + final_value_bytes;
        let (checkpoints_written, checkpoint_secs) = match &shared.ckpt {
            Some(c) => (
                c.written.load(Ordering::Relaxed),
                c.nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            ),
            None => (0, 0.0),
        };
        Ok(RunResult {
            values,
            metrics: EngineMetrics {
                supersteps,
                base_bytes,
                wall_secs: t_run.elapsed().as_secs_f64(),
                peak_bytes: shared.peak_bytes.load(Ordering::Relaxed),
                checkpoints_written,
                checkpoint_secs,
                respawns: 0,
                heartbeat_misses: 0,
                io_retries: crate::util::failpoints::io_retries()
                    .saturating_sub(io_retries_at_start),
            },
        })
    }
}

/// Per-worker start state for [`worker_loop`]: superstep 0 with
/// program-initialized values for a fresh run, or checkpoint-restored
/// state (in `my_vertices` order) for a resumed one.
struct WorkerStart<P: VertexProgram> {
    superstep: u32,
    values: Option<Vec<P::Value>>,
    halted: Option<Vec<bool>>,
}

/// Render a caught panic payload for [`EngineError::WorkerFailed`].
fn panic_payload(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Move the splittable messages of hot vertex `vid` out of `msgs` and into
/// the shared hot queue as chunks sized so every worker can get a share
/// (messages the program marks non-splittable stay with the owner).
fn offload_hot_messages<P: VertexProgram>(
    program: &P,
    owner: usize,
    vid: VertexId,
    msgs: &mut Vec<P::Msg>,
    num_workers: usize,
    shared: &Shared<P>,
) {
    let all = std::mem::take(msgs);
    let mut split = Vec::with_capacity(all.len());
    for m in all {
        if program.splittable(&m) {
            split.push(m);
        } else {
            msgs.push(m);
        }
    }
    if split.len() < HOT_MIN_SPLIT_MSGS {
        // Too few splittable messages to be worth the queue round-trip.
        msgs.extend(split);
        return;
    }
    // ~2 chunks per worker so the steal loop can rebalance stragglers.
    let chunk = (split.len().div_ceil(2 * num_workers)).max(HOT_MIN_CHUNK);
    let mut tasks = 0u64;
    let mut queue = shared.hot_queue.lock().unwrap();
    while !split.is_empty() {
        let at = split.len().saturating_sub(chunk);
        queue.push(HotTask {
            vid,
            owner,
            msgs: split.split_off(at),
        });
        tasks += 1;
    }
    drop(queue);
    shared.hot_tasks.fetch_add(tasks, Ordering::Relaxed);
}

/// Body of one worker thread.
// Allowed: one call site; the params are the per-worker slices of
// engine state, deliberately passed as disjoint borrows so the borrow
// checker can prove the workers' aliasing discipline.
#[allow(clippy::too_many_arguments)]
fn worker_loop<P: VertexProgram>(
    me: usize,
    graph: &Graph,
    part: &Partitioner,
    my_vertices: &[VertexId],
    program: &P,
    shared: &Shared<P>,
    opts: EngineOpts,
    graph_bytes: u64,
    start: WorkerStart<P>,
    remote: Option<&RemoteCtl<'_, P>>,
) -> Vec<P::Value> {
    // Hot splitting is pointless on a single worker or for a program that
    // never opts in; the decision must be uniform across workers (it adds
    // a barrier) and it is: every worker sees the same opts, partitioner
    // and program instance. In a sharded run the hot queue is shared
    // memory, so stealing is confined to *this shard's* workers: the
    // gate counts local workers, not the global worker space (the fix for
    // the cross-process stealing bug — see `EngineOpts::hot_split_cross_shard`).
    let local_workers = remote.map_or_else(|| part.num_workers(), |rc| rc.wps);
    let hot_threshold = match opts.hot_degree_threshold {
        Some(t) if local_workers > 1 && program.supports_hot_split() => Some(t),
        _ => None,
    };
    let mut values: Vec<P::Value> = start.values.unwrap_or_else(|| {
        my_vertices
            .iter()
            .map(|&v| program.init_value(v))
            .collect()
    });
    debug_assert_eq!(values.len(), my_vertices.len());
    let mut halted = start
        .halted
        .unwrap_or_else(|| vec![false; my_vertices.len()]);
    let mut cache = WorkerCache::new(opts.cache_capacity);
    let mut out: Vec<Vec<(VertexId, P::Msg)>> = (0..part.num_workers())
        .map(|_| Vec::new())
        .collect();
    // Per-vertex delivery buckets, indexed by the partitioner's dense local
    // index. Allocated once and reused across supersteps (each bucket keeps
    // its capacity), so steady-state delivery allocates nothing.
    let mut vertex_msgs: Vec<Vec<P::Msg>> = Vec::new();
    vertex_msgs.resize_with(my_vertices.len(), Vec::new);
    let mut superstep: u32 = start.superstep;
    let mut step_start = Instant::now();

    loop {
        // Published so the panic handler in `run_inner` can report where a
        // worker died; fetch_max because workers race past the barrier.
        shared.cur_superstep.fetch_max(superstep, Ordering::Relaxed);
        crate::util::failpoints::maybe_panic("engine.superstep");
        // ---- message delivery: bucket my inbox by local dense index. ----
        // A single O(msgs) counting/bucket pass replaces the former global
        // `sort_unstable_by_key` over the whole inbox (O(msgs log msgs)
        // with a comparison sort's branch misses); per-destination order is
        // unspecified either way and programs are required to be
        // order-independent (per-(walk, step) RNG streams).
        // See EXPERIMENTS.md §Perf.
        let parity = (superstep % 2) as usize;
        let mut inbox =
            std::mem::take(&mut *shared.inboxes[parity][me].lock().unwrap());
        for (vid, msg) in inbox.drain(..) {
            let li = part.local_index(vid);
            debug_assert!(
                li < my_vertices.len() && my_vertices[li] == vid,
                "message for {vid} routed to worker {me} (local index {li})"
            );
            vertex_msgs[li].push(msg);
        }
        // Hand the drained (empty) buffer back to the now-idle current-
        // parity slot so the allocation is reused two supersteps from now.
        {
            let mut slot = shared.inboxes[parity][me].lock().unwrap();
            if slot.capacity() < inbox.capacity() {
                *slot = inbox;
            }
        }

        // ---- compute phase ----
        let mut counters = LocalCounters::default();
        let t_compute = Instant::now();
        for (li, &vid) in my_vertices.iter().enumerate() {
            let msgs = &mut vertex_msgs[li];
            let active = !halted[li] || !msgs.is_empty();
            if !active {
                continue;
            }
            if let Some(threshold) = hot_threshold {
                if msgs.len() >= HOT_MIN_SPLIT_MSGS && graph.degree(vid) >= threshold as usize
                {
                    offload_hot_messages::<P>(program, me, vid, msgs, local_workers, shared);
                }
            }
            halted[li] = false;
            counters.active += 1;
            counters.msgs_handled += msgs.len() as u64;
            let mut ctx = Ctx::<P> {
                superstep,
                graph,
                part,
                me,
                executor: me,
                hot_chunk: false,
                cur_vid: vid,
                halt: false,
                out: &mut out,
                counters: &mut counters,
                cache: &mut cache,
            };
            program.compute(&mut ctx, vid, &mut values[li], msgs);
            msgs.clear(); // compute may only iterate; keep capacity for reuse
            halted[li] = ctx.halt;
        }
        let mut compute_nanos = t_compute.elapsed().as_nanos() as u64;

        // ---- hot-vertex work stealing ----
        if hot_threshold.is_some() {
            // Barrier: every worker has finished enqueueing before anyone
            // steals, so the queue length only decreases from here on.
            if shared.barrier.wait().poisoned() {
                return values;
            }
            let t_steal = Instant::now();
            loop {
                let task = shared.hot_queue.lock().unwrap().pop();
                let Some(mut task) = task else { break };
                counters.msgs_handled += task.msgs.len() as u64;
                // Ephemeral value; `me` impersonates the owner so every
                // partition-relative decision matches owner-side compute.
                let mut value = P::Value::default();
                let mut ctx = Ctx::<P> {
                    superstep,
                    graph,
                    part,
                    me: task.owner,
                    executor: me,
                    hot_chunk: true,
                    cur_vid: task.vid,
                    halt: false,
                    out: &mut out,
                    counters: &mut counters,
                    cache: &mut cache,
                };
                program.compute(&mut ctx, task.vid, &mut value, &mut task.msgs);
            }
            compute_nanos += t_steal.elapsed().as_nanos() as u64;
        }
        shared.worker_compute_nanos[me].store(compute_nanos, Ordering::Relaxed);
        shared.worker_msgs[me].store(counters.msgs_handled, Ordering::Relaxed);

        // ---- flush outgoing messages into destination inboxes ----
        // Within-shard destinations append straight into the next-parity
        // inbox. In a sharded run, messages for workers on other shards
        // are instead encoded with the real wire codec into the
        // per-destination-shard outbound buffer; the shard leader ships
        // them as Data frames at the barrier.
        for (dst_worker, buf) in out.iter_mut().enumerate() {
            if buf.is_empty() {
                continue;
            }
            if let Some(rc) = remote {
                let ds = dst_worker / rc.wps;
                if ds != rc.shard {
                    let mut ob = rc.outbound[ds].lock().unwrap();
                    for (dst, msg) in buf.drain(..) {
                        ob.sim_bytes += msg.wire_bytes();
                        ob.wire_bytes += (rc.encode_entry)(dst, &msg, &mut ob.bytes);
                        ob.msgs += 1;
                    }
                    continue;
                }
            }
            shared.inboxes[1 - parity][dst_worker]
                .lock()
                .unwrap()
                .append(buf);
        }

        // ---- merge counters ----
        shared.msgs_local.fetch_add(counters.msgs_local, Ordering::Relaxed);
        shared
            .msgs_remote
            .fetch_add(counters.msgs_remote, Ordering::Relaxed);
        shared
            .bytes_local
            .fetch_add(counters.bytes_local, Ordering::Relaxed);
        shared
            .bytes_remote
            .fetch_add(counters.bytes_remote, Ordering::Relaxed);
        shared.active.fetch_add(counters.active, Ordering::Relaxed);
        let live = halted.iter().filter(|&&h| !h).count() as u64;
        shared.not_halted.fetch_add(live, Ordering::Relaxed);
        shared.cache_bytes.fetch_add(cache.bytes, Ordering::Relaxed);
        let vbytes: u64 = values.iter().map(|v| program.value_bytes(v)).sum();
        shared.value_bytes.fetch_add(vbytes, Ordering::Relaxed);

        // ---- barrier: leader plays master ----
        let wait = shared.barrier.wait();
        if wait.poisoned() {
            return values;
        }
        if wait.is_leader() {
            match remote {
                // Shard leader: ship cross-shard messages and this shard's
                // barrier report to the coordinator, then apply its
                // decision. The master role lives on the coordinator.
                Some(rc) => shard_leader_exchange::<P>(rc, part, shared, superstep),
                // In-process leader plays master directly.
                None => master_step::<P>(shared, opts, graph_bytes, superstep, &step_start),
            }
            reset_step_accumulators::<P>(shared);
        }
        // Second barrier: everyone observes the leader's decision.
        if shared.barrier.wait().poisoned() {
            return values;
        }
        if shared.stop.load(Ordering::Relaxed) {
            break;
        }

        // ---- checkpoint phase (only on supersteps the leader marked) ----
        // Two extra barriers, paid only on checkpoint supersteps: one so
        // every worker's encoded part is in place before the leader
        // assembles, one so the leader's write outcome is visible to all.
        if let Some(ckpt) = shared.ckpt.as_ref() {
            if ckpt.due.load(Ordering::Relaxed) {
                let mut enc = EncodedPart::default();
                for (li, &vid) in my_vertices.iter().enumerate() {
                    enc.values.extend_from_slice(&vid.to_le_bytes());
                    enc.values.push(u8::from(halted[li]));
                    (ckpt.persist_value)(&values[li], &mut enc.values);
                }
                enc.value_count = my_vertices.len() as u64;
                {
                    // The *next*-parity inbox holds exactly the in-flight
                    // messages the resumed superstep will deliver.
                    let inbox = shared.inboxes[1 - parity][me].lock().unwrap();
                    enc.msg_count = inbox.len() as u64;
                    for (dst, msg) in inbox.iter() {
                        enc.msgs.extend_from_slice(&dst.to_le_bytes());
                        (ckpt.persist_msg)(msg, &mut enc.msgs);
                    }
                }
                ckpt.parts.lock().unwrap()[me] = Some(enc);
                let wait = shared.barrier.wait();
                if wait.poisoned() {
                    return values;
                }
                if wait.is_leader() {
                    let parts: Vec<EncodedPart> = {
                        let mut slots = ckpt.parts.lock().unwrap();
                        slots.iter_mut().map(|s| s.take().unwrap_or_default()).collect()
                    };
                    match remote {
                        // Shard leader: ship this shard's encoded part to
                        // the coordinator, which assembles all shards into
                        // one FN2VCKP1 file, and wait for the verdict.
                        Some(rc) => {
                            shard_leader_checkpoint::<P>(rc, part, shared, superstep, parts)
                        }
                        None => {
                            let spec = ckpt
                                .spec
                                .as_ref()
                                .expect("in-process checkpoint runs carry a spec");
                            let t_ckpt = Instant::now();
                            let written = checkpoint::write_checkpoint(
                                spec,
                                superstep + 1,
                                graph.num_vertices() as u32,
                                parts,
                            );
                            match written {
                                Ok(_) => {
                                    ckpt.written.fetch_add(1, Ordering::Relaxed);
                                    let nanos = t_ckpt.elapsed().as_nanos() as u64;
                                    ckpt.nanos.fetch_add(nanos, Ordering::Relaxed);
                                }
                                Err(e) => {
                                    let mut err = shared.error.lock().unwrap();
                                    if err.is_none() {
                                        *err = Some(EngineError::Checkpoint {
                                            superstep,
                                            detail: e.to_string(),
                                        });
                                    }
                                    drop(err);
                                    shared.stop.store(true, Ordering::Relaxed);
                                }
                            }
                        }
                    }
                    ckpt.due.store(false, Ordering::Relaxed);
                }
                if shared.barrier.wait().poisoned() {
                    return values;
                }
                if shared.stop.load(Ordering::Relaxed) {
                    break;
                }
            }
        }

        superstep += 1;
        step_start = Instant::now();
    }
    values
}

/// The in-process leader's master role: aggregate the superstep's
/// counters into a [`SuperstepMetrics`] record, check the memory budget,
/// decide termination, and mark checkpoint cadence.
fn master_step<P: VertexProgram>(
    shared: &Shared<P>,
    opts: EngineOpts,
    graph_bytes: u64,
    superstep: u32,
    step_start: &Instant,
) {
    let msg_mem =
        shared.bytes_local.load(Ordering::Relaxed) + shared.bytes_remote.load(Ordering::Relaxed);
    let cache_total = shared.cache_bytes.load(Ordering::Relaxed);
    let value_total = shared.value_bytes.load(Ordering::Relaxed);
    let sm = SuperstepMetrics {
        superstep,
        active_vertices: shared.active.load(Ordering::Relaxed),
        msgs_local: shared.msgs_local.load(Ordering::Relaxed),
        msgs_remote: shared.msgs_remote.load(Ordering::Relaxed),
        bytes_local: shared.bytes_local.load(Ordering::Relaxed),
        bytes_remote: shared.bytes_remote.load(Ordering::Relaxed),
        msg_mem_bytes: msg_mem,
        cache_bytes: cache_total,
        wall_secs: step_start.elapsed().as_secs_f64(),
        worker_compute_secs: shared
            .worker_compute_nanos
            .iter()
            .map(|c| c.load(Ordering::Relaxed) as f64 * 1e-9)
            .collect(),
        worker_msgs_handled: shared
            .worker_msgs
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect(),
        hot_split_tasks: shared.hot_tasks.load(Ordering::Relaxed),
    };
    let total_msgs = sm.msgs_local + sm.msgs_remote;
    let not_halted = shared.not_halted.load(Ordering::Relaxed);
    shared.metrics.lock().unwrap().push(sm);

    let current = graph_bytes + value_total + msg_mem + cache_total;
    shared.peak_bytes.fetch_max(current, Ordering::Relaxed);

    // Termination / error decisions.
    let mut stopping = false;
    if let Some(budget) = opts.memory_budget {
        if current > budget {
            *shared.error.lock().unwrap() = Some(EngineError::OutOfMemory {
                superstep,
                bytes: current,
            });
            stopping = true;
        }
    }
    if total_msgs == 0 && not_halted == 0 {
        stopping = true;
    } else if superstep + 1 >= opts.max_supersteps {
        *shared.error.lock().unwrap() = Some(EngineError::DidNotTerminate {
            supersteps: superstep + 1,
        });
        stopping = true;
    }
    if stopping {
        shared.stop.store(true, Ordering::Relaxed);
    } else if let Some(ckpt) = shared.ckpt.as_ref() {
        // Checkpoint cadence: after superstep boundaries where one more
        // superstep will actually run. `superstep + 1` is the superstep a
        // resume would execute next. Shard runs have no local spec — the
        // coordinator owns the cadence and signals it in the decision.
        if let Some(spec) = ckpt.spec.as_ref() {
            if (superstep + 1) % spec.every.max(1) == 0 {
                ckpt.due.store(true, Ordering::Relaxed);
            }
        }
    }
}

/// Reset the per-superstep accumulators (leader-only, between barriers).
fn reset_step_accumulators<P: VertexProgram>(shared: &Shared<P>) {
    shared.msgs_local.store(0, Ordering::Relaxed);
    shared.msgs_remote.store(0, Ordering::Relaxed);
    shared.bytes_local.store(0, Ordering::Relaxed);
    shared.bytes_remote.store(0, Ordering::Relaxed);
    shared.active.store(0, Ordering::Relaxed);
    shared.not_halted.store(0, Ordering::Relaxed);
    shared.cache_bytes.store(0, Ordering::Relaxed);
    shared.value_bytes.store(0, Ordering::Relaxed);
    shared.hot_tasks.store(0, Ordering::Relaxed);
}

/// Record a shard-side failure (first error wins) and stop the run.
fn fail_shard<P: VertexProgram>(shared: &Shared<P>, err: EngineError) {
    let mut slot = shared.error.lock().unwrap_or_else(|p| p.into_inner());
    if slot.is_none() {
        *slot = Some(err);
    }
    drop(slot);
    shared.stop.store(true, Ordering::Relaxed);
}

fn shard_err(shard: usize, detail: String) -> EngineError {
    EngineError::ShardFailed { shard, detail }
}

/// The shard leader's half of the coordinator barrier protocol for one
/// superstep: drain the outbound buffers into one `Data` frame per
/// destination shard, send this shard's `Barrier` report, then receive
/// until the coordinator's `Decision` arrives — delivering any forwarded
/// `Data` frames into the local inboxes on the way.
///
/// Safe to deliver while siblings wait: non-leader workers are parked at
/// the decision barrier, and per-connection FIFO ordering guarantees every
/// `Data` frame for superstep `s` is forwarded before the coordinator's
/// `Decision` for `s` (the coordinator only decides after all barrier
/// reports, and forwards each shard's data before processing its barrier).
fn shard_leader_exchange<P: VertexProgram>(
    rc: &RemoteCtl<'_, P>,
    part: &Partitioner,
    shared: &Shared<P>,
    superstep: u32,
) {
    if let Err(e) = shard_exchange_inner(rc, part, shared, superstep) {
        fail_shard(shared, e);
    }
}

fn shard_exchange_inner<P: VertexProgram>(
    rc: &RemoteCtl<'_, P>,
    part: &Partitioner,
    shared: &Shared<P>,
    superstep: u32,
) -> Result<(), EngineError> {
    let me = rc.shard;
    let my_workers = rc.first..rc.first + rc.wps;
    let mut report = ShardReport {
        superstep,
        active: shared.active.load(Ordering::Relaxed),
        not_halted: shared.not_halted.load(Ordering::Relaxed),
        msgs_within: 0,
        msgs_cross: 0,
        bytes_within: 0,
        bytes_cross_sim: 0,
        bytes_cross_wire: 0,
        cache_bytes: shared.cache_bytes.load(Ordering::Relaxed),
        value_bytes: shared.value_bytes.load(Ordering::Relaxed),
        hot_tasks: shared.hot_tasks.load(Ordering::Relaxed),
        compute_nanos: my_workers
            .clone()
            .map(|w| shared.worker_compute_nanos[w].load(Ordering::Relaxed))
            .collect(),
        msgs_handled: my_workers
            .clone()
            .map(|w| shared.worker_msgs[w].load(Ordering::Relaxed))
            .collect(),
    };
    let mut conn = rc.conn.lock().unwrap_or_else(|p| p.into_inner());
    for ds in 0..rc.shards {
        if ds == me {
            continue;
        }
        let payload = {
            let mut ob = rc.outbound[ds].lock().unwrap_or_else(|p| p.into_inner());
            report.msgs_cross += ob.msgs;
            report.bytes_cross_sim += ob.sim_bytes;
            report.bytes_cross_wire += ob.wire_bytes;
            ob.msgs = 0;
            ob.sim_bytes = 0;
            ob.wire_bytes = 0;
            std::mem::take(&mut ob.bytes)
        };
        if payload.is_empty() {
            continue;
        }
        conn.send(&Frame::new(
            FrameKind::Data,
            me as u8,
            ds as u8,
            superstep,
            payload,
        ))
        .map_err(|e| shard_err(me, format!("sending data frame: {e}")))?;
    }
    // Within-shard traffic = everything the simulated accounting charged,
    // minus what actually crossed the process boundary. The coordinator
    // recombines the two so budget decisions match the in-process engine
    // bit for bit while `bytes_remote` reports *measured* frame bytes.
    let msgs_total =
        shared.msgs_local.load(Ordering::Relaxed) + shared.msgs_remote.load(Ordering::Relaxed);
    let bytes_total =
        shared.bytes_local.load(Ordering::Relaxed) + shared.bytes_remote.load(Ordering::Relaxed);
    report.msgs_within = msgs_total - report.msgs_cross;
    report.bytes_within = bytes_total - report.bytes_cross_sim;
    conn.send(&Frame::new(
        FrameKind::Barrier,
        me as u8,
        COORD_ID,
        superstep,
        report.encode(),
    ))
    .map_err(|e| shard_err(me, format!("sending barrier report: {e}")))?;

    loop {
        let frame = conn
            .recv()
            .map_err(|e| shard_err(me, format!("awaiting decision: {e}")))?;
        match frame.kind {
            FrameKind::Data => {
                let t = frame.superstep;
                if t != superstep && t != superstep + 1 {
                    return Err(shard_err(
                        me,
                        format!("data frame for superstep {t} during superstep {superstep}"),
                    ));
                }
                deliver_data_frame(rc, part, shared, &frame)?;
            }
            FrameKind::Decision => {
                let d = Decision::decode(&frame.payload)
                    .map_err(|e| shard_err(me, format!("bad decision frame: {e}")))?;
                apply_decision(shared, d, me)?;
                return Ok(());
            }
            other => {
                return Err(shard_err(
                    me,
                    format!("unexpected {other:?} frame while awaiting decision"),
                ));
            }
        }
    }
}

/// Decode a forwarded `Data` frame and push its entries into the local
/// next-parity inboxes. Messages tagged with superstep `t` were sent
/// *during* `t`, so their delivery superstep is `t + 1` and the right
/// inbox is `inboxes[(t + 1) % 2]`.
fn deliver_data_frame<P: VertexProgram>(
    rc: &RemoteCtl<'_, P>,
    part: &Partitioner,
    shared: &Shared<P>,
    frame: &Frame,
) -> Result<(), EngineError> {
    let me = rc.shard;
    let slot = ((frame.superstep as usize) + 1) % 2;
    let mut r = ByteReader::new(&frame.payload);
    while !r.is_empty() {
        let (dst, msg) = (rc.decode_entry)(&mut r)
            .map_err(|e| shard_err(me, format!("bad data entry from shard {}: {e}", frame.src)))?;
        let dw = part.worker_of(dst);
        if dw / rc.wps != me {
            return Err(shard_err(
                me,
                format!("misrouted message for vertex {dst} (worker {dw})"),
            ));
        }
        shared.inboxes[slot][dw].lock().unwrap().push((dst, msg));
    }
    Ok(())
}

/// Apply a coordinator decision on the shard. Stop decisions reproduce the
/// in-process master's typed errors so the session driver's FN-Multi
/// degradation sees exactly what it would see single-process.
fn apply_decision<P: VertexProgram>(
    shared: &Shared<P>,
    d: Decision,
    me: usize,
) -> Result<(), EngineError> {
    match d {
        Decision::Continue { checkpoint } => {
            if checkpoint {
                if let Some(ckpt) = shared.ckpt.as_ref() {
                    ckpt.due.store(true, Ordering::Relaxed);
                } else {
                    return Err(shard_err(
                        me,
                        "checkpoint requested but run has no checkpoint control".to_string(),
                    ));
                }
            }
        }
        Decision::Stop => shared.stop.store(true, Ordering::Relaxed),
        Decision::StopOom { superstep, bytes } => {
            fail_shard(
                shared,
                EngineError::OutOfMemory { superstep, bytes },
            );
        }
        Decision::StopCap { supersteps } => {
            fail_shard(shared, EngineError::DidNotTerminate { supersteps });
        }
        Decision::Abort { detail } => {
            return Err(shard_err(me, format!("unit aborted: {detail}")));
        }
    }
    Ok(())
}

/// The shard leader's half of the checkpoint phase: merge this shard's
/// per-worker encoded parts into one `CkptPart` frame, ship it, and wait
/// for the coordinator's `CkptResult` verdict (the coordinator assembles
/// every shard's part into a single FN2VCKP1 file, so sharded checkpoints
/// are interchangeable with in-process ones).
fn shard_leader_checkpoint<P: VertexProgram>(
    rc: &RemoteCtl<'_, P>,
    part: &Partitioner,
    shared: &Shared<P>,
    superstep: u32,
    parts: Vec<EncodedPart>,
) {
    if let Err(e) = shard_checkpoint_inner(rc, part, shared, superstep, parts) {
        fail_shard(shared, e);
    }
}

fn shard_checkpoint_inner<P: VertexProgram>(
    rc: &RemoteCtl<'_, P>,
    part: &Partitioner,
    shared: &Shared<P>,
    superstep: u32,
    parts: Vec<EncodedPart>,
) -> Result<(), EngineError> {
    let me = rc.shard;
    let mut merged = EncodedPart::default();
    for p in parts {
        merged.value_count += p.value_count;
        merged.values.extend_from_slice(&p.values);
        merged.msg_count += p.msg_count;
        merged.msgs.extend_from_slice(&p.msgs);
    }
    let mut payload =
        Vec::with_capacity(32 + merged.values.len() + merged.msgs.len());
    payload.extend_from_slice(&merged.value_count.to_le_bytes());
    payload.extend_from_slice(&(merged.values.len() as u64).to_le_bytes());
    payload.extend_from_slice(&merged.values);
    payload.extend_from_slice(&merged.msg_count.to_le_bytes());
    payload.extend_from_slice(&(merged.msgs.len() as u64).to_le_bytes());
    payload.extend_from_slice(&merged.msgs);

    let mut conn = rc.conn.lock().unwrap_or_else(|p| p.into_inner());
    conn.send(&Frame::new(
        FrameKind::CkptPart,
        me as u8,
        COORD_ID,
        superstep,
        payload,
    ))
    .map_err(|e| shard_err(me, format!("sending checkpoint part: {e}")))?;

    loop {
        let frame = conn
            .recv()
            .map_err(|e| shard_err(me, format!("awaiting checkpoint result: {e}")))?;
        match frame.kind {
            FrameKind::Data => deliver_data_frame(rc, part, shared, &frame)?,
            FrameKind::CkptResult => {
                let mut r = ByteReader::new(&frame.payload);
                let ok = r
                    .u8()
                    .map_err(|e| shard_err(me, format!("bad checkpoint result: {e}")))?;
                if ok == 0 {
                    let rem = r.remaining();
                    let detail =
                        String::from_utf8_lossy(r.take(rem).unwrap_or_default()).into_owned();
                    // Mirror the in-process write-failure path: typed
                    // error, stop the run, no partial progress claimed.
                    fail_shard(shared, EngineError::Checkpoint { superstep, detail });
                }
                return Ok(());
            }
            other => {
                return Err(shard_err(
                    me,
                    format!("unexpected {other:?} frame while awaiting checkpoint result"),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{er_graph, GenConfig};
    use crate::graph::GraphBuilder;
    use crate::util::propkit::{forall, Gen};

    /// Test message: a bare u64 charged at 8 wire bytes.
    struct IdMsg(u64);
    impl Message for IdMsg {
        fn wire_bytes(&self) -> u64 {
            8
        }
    }

    /// Each vertex broadcasts its id to neighbors for `rounds` supersteps
    /// and accumulates everything it receives. Final value is
    /// `rounds * Σ neighbor ids` — checkable in closed form.
    struct SumIds {
        rounds: u32,
    }

    impl VertexProgram for SumIds {
        type Value = u64;
        type Msg = IdMsg;

        fn compute(
            &self,
            ctx: &mut Ctx<'_, Self>,
            vid: VertexId,
            value: &mut u64,
            msgs: &mut Vec<IdMsg>,
        ) {
            for m in msgs.iter() {
                *value += m.0;
            }
            if ctx.superstep() < self.rounds {
                for &nb in ctx.neighbors() {
                    ctx.send(nb, IdMsg(vid as u64));
                }
            } else {
                ctx.vote_to_halt();
            }
        }
    }

    fn path_graph(n: usize) -> crate::graph::Graph {
        let mut b = GraphBuilder::new_undirected(n);
        for i in 0..n - 1 {
            b.add_edge(i as u32, i as u32 + 1, 1.0);
        }
        b.build()
    }

    fn expected_sum_ids(g: &crate::graph::Graph, rounds: u64) -> Vec<u64> {
        g.vertices()
            .map(|v| {
                rounds
                    * g.neighbors(v)
                        .iter()
                        .map(|&u| u as u64)
                        .sum::<u64>()
            })
            .collect()
    }

    #[test]
    fn bsp_semantics_match_closed_form() {
        let g = path_graph(10);
        let eng = Engine::new(
            &g,
            Partitioner::hash(3),
            SumIds { rounds: 4 },
            EngineOpts::default(),
        );
        let out = eng.run().unwrap();
        assert_eq!(out.values, expected_sum_ids(&g, 4));
        // rounds+1 supersteps: send in 0..rounds, final receive+halt.
        assert_eq!(out.metrics.num_supersteps(), 5);
    }

    #[test]
    fn results_independent_of_worker_count() {
        let g = er_graph(&GenConfig::new(300, 8, 17));
        let mut reference: Option<Vec<u64>> = None;
        for workers in [1usize, 2, 5, 8] {
            let eng = Engine::new(
                &g,
                Partitioner::hash(workers),
                SumIds { rounds: 3 },
                EngineOpts::default(),
            );
            let out = eng.run().unwrap();
            match &reference {
                None => reference = Some(out.values),
                Some(r) => assert_eq!(&out.values, r, "workers={workers} diverged"),
            }
        }
        assert_eq!(reference.unwrap(), expected_sum_ids(&g, 3));
    }

    #[test]
    fn results_identical_under_range_partitioning() {
        // The bucket delivery keys on Partitioner::local_index; both
        // schemes must deliver every message to the right vertex.
        let g = er_graph(&GenConfig::new(250, 7, 23));
        let expect = expected_sum_ids(&g, 3);
        for workers in [1usize, 3, 7] {
            for part in [
                Partitioner::hash(workers),
                Partitioner::range(workers, g.num_vertices()),
                Partitioner::degree_aware(workers, &g),
            ] {
                let scheme = part.scheme_name();
                let eng = Engine::new(&g, part, SumIds { rounds: 3 }, EngineOpts::default());
                let out = eng.run().unwrap();
                assert_eq!(out.values, expect, "workers={workers} part={scheme}");
            }
        }
    }

    #[test]
    fn worker_plan_matches_partitioner_and_supports_reuse() {
        let g = er_graph(&GenConfig::new(100, 5, 3));
        for part in [
            Partitioner::hash(3),
            Partitioner::range(3, 100),
            Partitioner::degree_aware(3, &g),
        ] {
            let plan = WorkerPlan::new(&part, 100);
            for w in 0..3 {
                assert_eq!(
                    plan.vertices(w),
                    part.vertices_of(w, 100).as_slice(),
                    "scheme {}",
                    part.scheme_name()
                );
            }
            // One engine, one plan, many runs: the prepared-session path.
            let eng = Engine::new(&g, part, SumIds { rounds: 2 }, EngineOpts::default());
            let a = eng.run_on(&plan).unwrap();
            let b = eng.run_on(&plan).unwrap();
            assert_eq!(a.values, b.values);
            assert_eq!(a.values, expected_sum_ids(&g, 2));
        }
    }

    #[test]
    fn message_accounting_splits_local_remote() {
        // Path 0-1-2-3 on 2 hash workers: {0,2} on w0, {1,3} on w1.
        // Every edge crosses workers, so all messages are remote.
        let g = path_graph(4);
        let eng = Engine::new(
            &g,
            Partitioner::hash(2),
            SumIds { rounds: 1 },
            EngineOpts::default(),
        );
        let out = eng.run().unwrap();
        let s0 = &out.metrics.supersteps[0];
        // 2*|E| directed sends at superstep 0 = 6 messages, all remote.
        assert_eq!(s0.msgs_remote, 6);
        assert_eq!(s0.msgs_local, 0);
        assert_eq!(s0.bytes_remote, 48);
        assert_eq!(s0.msg_mem_bytes, 48);

        // Same graph, 1 worker: everything is local.
        let eng1 = Engine::new(
            &g,
            Partitioner::hash(1),
            SumIds { rounds: 1 },
            EngineOpts::default(),
        );
        let out1 = eng1.run().unwrap();
        let t0 = &out1.metrics.supersteps[0];
        assert_eq!(t0.msgs_local, 6);
        assert_eq!(t0.msgs_remote, 0);
    }

    #[test]
    fn memory_budget_triggers_simulated_oom() {
        let g = er_graph(&GenConfig::new(500, 10, 5));
        let eng = Engine::new(
            &g,
            Partitioner::hash(4),
            SumIds { rounds: 50 },
            EngineOpts {
                memory_budget: Some(g.memory_bytes() + 100), // no message headroom
                ..Default::default()
            },
        );
        match eng.run() {
            Err(EngineError::OutOfMemory { .. }) => {}
            other => panic!("expected OOM, got {:?}", other.err()),
        }
    }

    #[test]
    fn memory_budget_counts_sampler_tables_on_weighted_graphs() {
        // A *weighted* graph so the FN-Reject alias tables are non-empty
        // (unit-weight graphs store the free Uniform marker): once built,
        // a budget that clears the topology but not the tables must OOM.
        let mut b = GraphBuilder::new_undirected(2000);
        for v in 0..2000u32 {
            b.add_edge(v, (v * 7 + 1) % 2000, 1.5);
            b.add_edge(v, (v * 13 + 3) % 2000, 0.5);
        }
        let g = b.build();
        let tables = g.first_order_tables();
        assert!(tables.memory_bytes() > 0, "weighted graph must have tables");
        assert_eq!(g.resident_bytes(), g.memory_bytes() + tables.memory_bytes());

        // Budget below resident graph state: OOMs at the first barrier
        // (this exact run survived when only memory_bytes() was charged).
        let eng = Engine::new(
            &g,
            Partitioner::hash(2),
            SumIds { rounds: 1 },
            EngineOpts {
                memory_budget: Some(g.memory_bytes() + tables.memory_bytes() / 2),
                ..Default::default()
            },
        );
        match eng.run() {
            Err(EngineError::OutOfMemory { .. }) => {}
            other => panic!("expected OOM, got {:?}", other.err()),
        }

        // Same run with honest headroom over resident state completes.
        let eng = Engine::new(
            &g,
            Partitioner::hash(2),
            SumIds { rounds: 1 },
            EngineOpts {
                memory_budget: Some(g.resident_bytes() + 10_000_000),
                ..Default::default()
            },
        );
        assert!(eng.run().is_ok());
    }

    #[test]
    fn runaway_program_hits_superstep_cap() {
        let g = path_graph(4);
        let eng = Engine::new(
            &g,
            Partitioner::hash(2),
            SumIds { rounds: u32::MAX },
            EngineOpts {
                max_supersteps: 10,
                ..Default::default()
            },
        );
        match eng.run() {
            Err(EngineError::DidNotTerminate { supersteps }) => {
                assert_eq!(supersteps, 10)
            }
            other => panic!("expected cap, got {:?}", other.err()),
        }
    }

    /// Program that checks the FN-Local access rule: `local_neighbors`
    /// answers for same-worker vertices and refuses remote ones.
    struct LocalProbe;
    impl VertexProgram for LocalProbe {
        type Value = u64;
        type Msg = IdMsg;

        fn compute(
            &self,
            ctx: &mut Ctx<'_, Self>,
            _vid: VertexId,
            value: &mut u64,
            _msgs: &mut Vec<IdMsg>,
        ) {
            for v in 0..ctx.num_vertices() as VertexId {
                let got = ctx.local_neighbors(v).is_some();
                let same = ctx.worker_of(v) == ctx.my_worker();
                assert_eq!(got, same, "local access rule violated for {v}");
                if got {
                    *value += 1;
                }
            }
            ctx.vote_to_halt();
        }
    }

    #[test]
    fn local_access_respects_partition_boundary() {
        let g = path_graph(12);
        let eng = Engine::new(
            &g,
            Partitioner::hash(3),
            LocalProbe,
            EngineOpts::default(),
        );
        let out = eng.run().unwrap();
        // Each vertex saw exactly the 4 vertices of its own worker.
        assert!(out.values.iter().all(|&c| c == 4));
    }

    /// Cache probe: vertex 0 inserts, every same-worker vertex must hit.
    struct CacheProbe;
    impl VertexProgram for CacheProbe {
        type Value = u64;
        type Msg = IdMsg;

        fn compute(
            &self,
            ctx: &mut Ctx<'_, Self>,
            vid: VertexId,
            value: &mut u64,
            _msgs: &mut Vec<IdMsg>,
        ) {
            if ctx.superstep() == 0 {
                // One vertex per worker (the least id = worker id for hash
                // partitioning) populates the cache.
                if (vid as usize) < ctx.num_workers() {
                    let ok = ctx.cache_put(999_999, Arc::from(&[1u32, 2, 3][..]));
                    assert!(ok);
                }
                // Everyone runs next step too.
            } else {
                *value = ctx.cache_get(999_999).map(|n| n.len() as u64).unwrap_or(0);
                ctx.vote_to_halt();
            }
        }
    }

    #[test]
    fn worker_cache_is_shared_within_worker() {
        let g = path_graph(8);
        let eng = Engine::new(
            &g,
            Partitioner::hash(2),
            CacheProbe,
            EngineOpts::default(),
        );
        let out = eng.run().unwrap();
        assert!(out.values.iter().all(|&v| v == 3), "{:?}", out.values);
        // Cache bytes accounted: 2 workers * (3*4 + 16) bytes.
        let last = out.metrics.supersteps.last().unwrap();
        assert_eq!(last.cache_bytes, 2 * (12 + 16));
    }

    #[test]
    fn cache_capacity_rejects_when_full() {
        struct CapProbe;
        impl VertexProgram for CapProbe {
            type Value = u64;
            type Msg = IdMsg;
            fn compute(
                &self,
                ctx: &mut Ctx<'_, Self>,
                vid: VertexId,
                value: &mut u64,
                _msgs: &mut Vec<IdMsg>,
            ) {
                if vid == 0 {
                    let big: Arc<[u32]> = (0..100u32).collect::<Vec<_>>().into();
                    assert!(ctx.cache_put(1, big.clone()));
                    // Second insert exceeds the 500-byte capacity.
                    assert!(!ctx.cache_put(2, big));
                    *value = 1;
                }
                ctx.vote_to_halt();
            }
        }
        let g = path_graph(4);
        let eng = Engine::new(
            &g,
            Partitioner::hash(1),
            CapProbe,
            EngineOpts {
                cache_capacity: Some(500),
                ..Default::default()
            },
        );
        let out = eng.run().unwrap();
        assert_eq!(out.values[0], 1);
    }

    /// Star hub load generator: every leaf sends `PINGS` pings to the hub
    /// (vertex 0) at superstep 0; the hub answers one pong per ping; the
    /// leaves count pongs. Ping handling needs no persistent value, so it
    /// is declared splittable; pong counting mutates the leaf's value and
    /// is not.
    const PINGS: u32 = 8;

    enum PingMsg {
        Ping(VertexId),
        Pong,
    }
    impl Message for PingMsg {
        fn wire_bytes(&self) -> u64 {
            8
        }
    }

    struct PingHub;
    impl VertexProgram for PingHub {
        type Value = u64;
        type Msg = PingMsg;

        fn compute(
            &self,
            ctx: &mut Ctx<'_, Self>,
            vid: VertexId,
            value: &mut u64,
            msgs: &mut Vec<PingMsg>,
        ) {
            if ctx.superstep() == 0 {
                if vid != 0 {
                    for _ in 0..PINGS {
                        ctx.send(0, PingMsg::Ping(vid));
                    }
                }
            } else {
                for m in msgs.iter() {
                    match m {
                        PingMsg::Ping(src) => ctx.send(*src, PingMsg::Pong),
                        PingMsg::Pong => *value += 1,
                    }
                }
            }
            ctx.vote_to_halt();
        }

        fn supports_hot_split(&self) -> bool {
            true
        }

        fn splittable(&self, msg: &PingMsg) -> bool {
            matches!(msg, PingMsg::Ping(_))
        }
    }

    fn star_graph(leaves: usize) -> crate::graph::Graph {
        let mut b = GraphBuilder::new_undirected(leaves + 1);
        for v in 1..=leaves {
            b.add_edge(0, v as u32, 1.0);
        }
        b.build()
    }

    #[test]
    fn hot_split_shards_hub_messages_and_preserves_results() {
        let g = star_graph(63);
        let run = |part: Partitioner, hot: Option<u32>| {
            Engine::new(
                &g,
                part,
                PingHub,
                EngineOpts {
                    hot_degree_threshold: hot,
                    ..Default::default()
                },
            )
            .run()
            .unwrap()
        };
        let expect: Vec<u64> = (0..64u64).map(|v| if v == 0 { 0 } else { PINGS as u64 }).collect();

        let plain = run(Partitioner::hash(4), None);
        assert_eq!(plain.values, expect);
        assert_eq!(plain.metrics.total_hot_tasks(), 0);

        for part in [
            Partitioner::hash(4),
            Partitioner::range(4, g.num_vertices()),
            Partitioner::degree_aware(4, &g),
        ] {
            let hot = run(part, Some(32));
            assert_eq!(hot.values, expect, "hot split changed results");
            // 63 leaves * 8 pings = 504 splittable messages at the hub.
            assert!(
                hot.metrics.total_hot_tasks() >= 2,
                "hub messages were not sharded: {} tasks",
                hot.metrics.total_hot_tasks()
            );
        }
    }

    #[test]
    fn hot_split_disabled_on_single_worker() {
        let g = star_graph(63);
        let out = Engine::new(
            &g,
            Partitioner::hash(1),
            PingHub,
            EngineOpts {
                hot_degree_threshold: Some(1),
                ..Default::default()
            },
        )
        .run()
        .unwrap();
        assert_eq!(out.metrics.total_hot_tasks(), 0);
        assert_eq!(out.values[1], PINGS as u64);
    }

    #[test]
    fn per_worker_metrics_account_all_messages() {
        let g = star_graph(63);
        let out = Engine::new(
            &g,
            Partitioner::hash(4),
            PingHub,
            EngineOpts {
                hot_degree_threshold: Some(32),
                ..Default::default()
            },
        )
        .run()
        .unwrap();
        for s in &out.metrics.supersteps {
            assert_eq!(s.worker_compute_secs.len(), 4);
            assert_eq!(s.worker_msgs_handled.len(), 4);
            assert!(s.imbalance_ratio() >= 1.0 - 1e-9);
        }
        // Every delivered message is handled by exactly one worker:
        // 504 pings (superstep 1) + 504 pongs (superstep 2).
        let handled: u64 = out
            .metrics
            .supersteps
            .iter()
            .map(|s| s.worker_msgs_handled.iter().sum::<u64>())
            .sum();
        assert_eq!(handled, 1008);
        assert!(out.metrics.aggregate_imbalance_ratio() >= 1.0 - 1e-9);
        assert!(out.metrics.critical_path_secs() >= 0.0);
    }

    #[test]
    fn prop_engine_deterministic_across_workers_and_graphs() {
        forall("engine worker-count invariance", 12, |g: &mut Gen| {
            let n = g.usize_in(2, 120);
            let deg = g.usize_in(1, 6);
            let graph = er_graph(&GenConfig::new(n.max(2), deg, g.u64_in(0, 1 << 30)));
            let rounds = g.usize_in(1, 4) as u32;
            let w1 = g.usize_in(1, 6);
            let w2 = g.usize_in(1, 6);
            let run = |w: usize| {
                Engine::new(
                    &graph,
                    Partitioner::hash(w),
                    SumIds { rounds },
                    EngineOpts::default(),
                )
                .run()
                .unwrap()
                .values
            };
            assert_eq!(run(w1), run(w2));
        });
    }

    impl Persist for IdMsg {
        fn persist(&self, out: &mut Vec<u8>) {
            self.0.persist(out);
        }
        fn restore(r: &mut checkpoint::ByteReader<'_>) -> Result<Self, String> {
            Ok(IdMsg(u64::restore(r)?))
        }
    }

    /// Panics at one (superstep, vertex); otherwise behaves like SumIds.
    struct PanicAt {
        at: u32,
    }
    impl VertexProgram for PanicAt {
        type Value = u64;
        type Msg = IdMsg;

        fn compute(
            &self,
            ctx: &mut Ctx<'_, Self>,
            vid: VertexId,
            _value: &mut u64,
            _msgs: &mut Vec<IdMsg>,
        ) {
            assert!(
                ctx.superstep() != self.at || vid != 0,
                "boom at superstep {}",
                self.at
            );
            if ctx.superstep() < self.at + 4 {
                for &nb in ctx.neighbors() {
                    ctx.send(nb, IdMsg(1));
                }
            } else {
                ctx.vote_to_halt();
            }
        }
    }

    #[test]
    fn worker_panic_surfaces_as_typed_error() {
        let g = er_graph(&GenConfig::new(120, 5, 17));
        for workers in [1usize, 4] {
            let eng = Engine::new(
                &g,
                Partitioner::hash(workers),
                PanicAt { at: 2 },
                EngineOpts::default(),
            );
            match eng.run() {
                Err(EngineError::WorkerFailed {
                    superstep, payload, ..
                }) => {
                    assert_eq!(superstep, 2, "workers={workers}");
                    assert!(payload.contains("boom"), "payload: {payload}");
                }
                other => panic!(
                    "workers={workers}: expected WorkerFailed, got {:?}",
                    other.err()
                ),
            }
        }
    }

    fn engine_tmpdir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("fn2v-eng-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn checkpointed_run_matches_plain_and_every_checkpoint_resumes() {
        let g = er_graph(&GenConfig::new(150, 6, 11));
        let dir = engine_tmpdir("resume");
        let part = Partitioner::hash(3);
        let plan = WorkerPlan::new(&part, g.num_vertices());
        let eng = Engine::new(&g, part, SumIds { rounds: 5 }, EngineOpts::default());
        let plain = eng.run_on(&plan).unwrap();

        let mut spec = checkpoint::CheckpointSpec::new(dir.clone(), 1);
        spec.keep_all = true;
        spec.fingerprint = 42;
        let ckpt_run = eng.run_on_checkpointed(&plan, &spec).unwrap();
        assert_eq!(ckpt_run.values, plain.values, "checkpointing changed results");
        assert!(ckpt_run.metrics.checkpoints_written >= 4);

        let files = checkpoint::checkpoint_files(&dir);
        assert_eq!(files.len() as u64, ckpt_run.metrics.checkpoints_written);
        for file in &files {
            let ckpt = checkpoint::read_checkpoint(file, 10_000).unwrap();
            assert_eq!(ckpt.fingerprint, 42);
            let snap = ckpt.snapshot::<SumIds>().unwrap();
            // Resume on a *different* worker layout: messages re-bucket.
            let part2 = Partitioner::range(2, g.num_vertices());
            let plan2 = WorkerPlan::new(&part2, g.num_vertices());
            let eng2 = Engine::new(&g, part2, SumIds { rounds: 5 }, EngineOpts::default());
            let resumed = eng2.run_on_resumed(&plan2, snap, None).unwrap();
            assert_eq!(
                resumed.values,
                plain.values,
                "resume from {} diverged",
                file.display()
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_to_unwritable_dir_is_a_typed_error() {
        let g = path_graph(6);
        let part = Partitioner::hash(2);
        let plan = WorkerPlan::new(&part, g.num_vertices());
        let eng = Engine::new(&g, part, SumIds { rounds: 4 }, EngineOpts::default());
        // A regular *file* where the checkpoint dir should be.
        let dir = engine_tmpdir("baddir");
        std::fs::write(&dir, b"not a directory").unwrap();
        let spec = checkpoint::CheckpointSpec::new(dir.clone(), 1);
        match eng.run_on_checkpointed(&plan, &spec) {
            Err(EngineError::Checkpoint { .. }) => {}
            other => panic!("expected Checkpoint error, got {:?}", other.err()),
        }
        let _ = std::fs::remove_file(&dir);
    }
}
