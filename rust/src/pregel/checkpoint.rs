//! FN2VCKP1: checksummed engine-state checkpoints written at superstep
//! barriers, and the decode path that makes deterministic resume possible.
//!
//! A checkpoint captures everything the BSP engine needs to restart a run
//! mid-flight: every vertex's program value and halted flag, every message
//! in flight for the next superstep, and a session-supplied *schedule*
//! (FN-Multi round progress plus an opaque sink blob). Because walk
//! sampling draws only from counter-based RNG streams keyed by
//! `(seed, walk, step)` — never from engine state — restoring this snapshot
//! and re-running produces walks bit-identical to the uninterrupted run,
//! independent of worker count or partitioner.
//!
//! # On-disk layout (all little-endian)
//!
//! 64-byte header, mirroring the FN2VGRF2 discipline in
//! [`crate::graph::store`]:
//!
//! | bytes  | field                                      |
//! |--------|--------------------------------------------|
//! | 0..8   | magic `"FN2VCKP1"`                         |
//! | 8..12  | version (`1`)                              |
//! | 12..16 | superstep (the *next* superstep to run)    |
//! | 16..20 | pass                                       |
//! | 20..24 | round (in-flight FN-Multi round `e_r`)     |
//! | 24..28 | rounds (in-flight round count `e_R`)       |
//! | 28..32 | n (vertex count)                           |
//! | 32..40 | session fingerprint                        |
//! | 40..48 | payload length                             |
//! | 48..56 | fxhash64 of the payload                    |
//! | 56..64 | fxhash64 of header bytes 0..56             |
//!
//! The payload is a sequence of `[tag: u32][len: u64][body]` sections:
//! VALUES (1) holds `count: u64` then per-vertex `(vid: u32, halted: u8,
//! Persist-encoded value)`; MESSAGES (2) holds `count: u64` then
//! `(dst: u32, Persist-encoded message)` entries; SCHEDULE (3) holds the
//! encoded [`ScheduleState`]. Files are written to `<path>.tmp`, fsynced,
//! and atomically renamed, so a crash mid-write never leaves a partial
//! checkpoint on the final path; validation runs magic → version →
//! checksum → superstep → size → payload, each failure a typed
//! [`StoreError`] naming the field.

use std::fs::{self, File};
use std::io::{self, Write};
use std::path::{Path, PathBuf};

use crate::graph::store::{fxhash64, StoreError};
use crate::graph::VertexId;
use crate::util::failpoints;

use super::engine::VertexProgram;

const MAGIC: &[u8; 8] = b"FN2VCKP1";
const CKP_VERSION: u32 = 1;
const HEADER_BYTES: usize = 64;
const SEC_VALUES: u32 = 1;
const SEC_MESSAGES: u32 = 2;
const SEC_SCHEDULE: u32 = 3;

/// File extension of checkpoint files (`ckpt-<unit>-<superstep>.fn2vckp`).
pub const CKP_EXTENSION: &str = "fn2vckp";

/// State that survives a crash, encoded with explicit little-endian
/// framing. `restore` must consume exactly what `persist` wrote.
pub trait Persist: Sized {
    fn persist(&self, out: &mut Vec<u8>);
    fn restore(r: &mut ByteReader<'_>) -> Result<Self, String>;
}

impl Persist for u32 {
    fn persist(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn restore(r: &mut ByteReader<'_>) -> Result<Self, String> {
        r.u32()
    }
}

impl Persist for u64 {
    fn persist(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn restore(r: &mut ByteReader<'_>) -> Result<Self, String> {
        r.u64()
    }
}

impl Persist for f32 {
    fn persist(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn restore(r: &mut ByteReader<'_>) -> Result<Self, String> {
        r.f32()
    }
}

/// Bounds-checked little-endian cursor used by [`Persist::restore`].
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "need {n} bytes at offset {}, {} remain",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        let mut b = [0u8; 4];
        b.copy_from_slice(self.take(4)?);
        Ok(u32::from_le_bytes(b))
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        let mut b = [0u8; 8];
        b.copy_from_slice(self.take(8)?);
        Ok(u64::from_le_bytes(b))
    }

    pub fn f32(&mut self) -> Result<f32, String> {
        let mut b = [0u8; 4];
        b.copy_from_slice(self.take(4)?);
        Ok(f32::from_le_bytes(b))
    }
}

/// One work unit of a walk query: `(pass, round class)` — the granularity
/// at which the session delivers walks and the schedule records progress.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UnitId {
    pub pass: u32,
    /// Round class residue: the unit covered seeds with
    /// `vid % er_count == er`.
    pub er: u32,
    pub er_count: u32,
}

/// Session-level progress stored in the SCHEDULE section: completed units
/// since the start of the query, the remaining round classes of the
/// current pass (excluding the in-flight unit the engine snapshot covers),
/// and an opaque sink blob for sinks that can restore their own state.
#[derive(Clone, Debug, Default)]
pub struct ScheduleState {
    pub done: Vec<UnitId>,
    /// Remaining `(er, er_count)` classes of the in-flight pass.
    pub queue: Vec<(u32, u32)>,
    pub sink_blob: Option<Vec<u8>>,
}

/// Encode a [`ScheduleState`] into the SCHEDULE section body.
pub fn encode_schedule(s: &ScheduleState) -> Vec<u8> {
    let mut out = Vec::new();
    (s.done.len() as u64).persist(&mut out);
    for u in &s.done {
        u.pass.persist(&mut out);
        u.er.persist(&mut out);
        u.er_count.persist(&mut out);
    }
    (s.queue.len() as u64).persist(&mut out);
    for &(er, er_count) in &s.queue {
        er.persist(&mut out);
        er_count.persist(&mut out);
    }
    match &s.sink_blob {
        None => out.push(0),
        Some(blob) => {
            out.push(1);
            (blob.len() as u64).persist(&mut out);
            out.extend_from_slice(blob);
        }
    }
    out
}

fn decode_schedule(r: &mut ByteReader<'_>) -> Result<ScheduleState, String> {
    let mut s = ScheduleState::default();
    let done = r.u64()?;
    for _ in 0..done {
        s.done.push(UnitId {
            pass: r.u32()?,
            er: r.u32()?,
            er_count: r.u32()?,
        });
    }
    let queued = r.u64()?;
    for _ in 0..queued {
        s.queue.push((r.u32()?, r.u32()?));
    }
    if r.u8()? != 0 {
        let len = r.u64()? as usize;
        s.sink_blob = Some(r.take(len)?.to_vec());
    }
    if !r.is_empty() {
        return Err(format!("{} trailing bytes after schedule", r.remaining()));
    }
    Ok(s)
}

/// Identity of the in-flight unit, stamped into the header and filename.
#[derive(Clone, Copy, Debug, Default)]
pub struct CheckpointMeta {
    pub pass: u32,
    pub round: u32,
    pub rounds: u32,
    /// Completed-unit count at the time of the snapshot (filename prefix,
    /// so lexicographic order equals logical order).
    pub unit_seq: u32,
}

/// Everything the engine needs to write checkpoints during one run.
#[derive(Clone, Debug)]
pub struct CheckpointSpec {
    pub dir: PathBuf,
    /// Write every this-many supersteps (`1` = every barrier).
    pub every: u32,
    /// Keep every checkpoint instead of pruning to the newest two — the
    /// resume-conformance tests replay from *all* of them.
    pub keep_all: bool,
    pub meta: CheckpointMeta,
    /// Session fingerprint; resume refuses checkpoints from a different
    /// (graph, config, request) triple.
    pub fingerprint: u64,
    /// Pre-encoded [`ScheduleState`] (see [`encode_schedule`]).
    pub schedule: Vec<u8>,
}

impl CheckpointSpec {
    pub fn new(dir: impl Into<PathBuf>, every: u32) -> Self {
        CheckpointSpec {
            dir: dir.into(),
            every: every.max(1),
            keep_all: false,
            meta: CheckpointMeta::default(),
            fingerprint: 0,
            schedule: encode_schedule(&ScheduleState::default()),
        }
    }
}

/// One worker's encoded slice of the snapshot (values + next-superstep
/// inbox), produced between the checkpoint barriers.
#[derive(Default)]
pub(crate) struct EncodedPart {
    pub(crate) value_count: u64,
    pub(crate) values: Vec<u8>,
    pub(crate) msg_count: u64,
    pub(crate) msgs: Vec<u8>,
}

/// Dense engine state reconstructed from a checkpoint, consumable by
/// `Engine::run_on_resumed`.
pub struct EngineSnapshot<P: VertexProgram> {
    /// The superstep the resumed run executes first.
    pub superstep: u32,
    pub values: Vec<P::Value>,
    pub halted: Vec<bool>,
    pub messages: Vec<(VertexId, P::Msg)>,
}

fn section(out: &mut Vec<u8>, tag: u32, body: &[u8]) {
    tag.persist(out);
    (body.len() as u64).persist(out);
    out.extend_from_slice(body);
}

/// Assemble and atomically write one checkpoint; returns its final path.
/// `superstep` is the next superstep the resumed run would execute.
pub(crate) fn write_checkpoint(
    spec: &CheckpointSpec,
    superstep: u32,
    n: u32,
    parts: Vec<EncodedPart>,
) -> Result<PathBuf, StoreError> {
    fs::create_dir_all(&spec.dir)
        .map_err(|e| StoreError::io(format!("create checkpoint dir {}", spec.dir.display()), e))?;

    let mut values = Vec::new();
    let mut msgs = Vec::new();
    let (mut value_count, mut msg_count) = (0u64, 0u64);
    for p in &parts {
        value_count += p.value_count;
        msg_count += p.msg_count;
    }
    value_count.persist(&mut values);
    msg_count.persist(&mut msgs);
    for p in &parts {
        values.extend_from_slice(&p.values);
        msgs.extend_from_slice(&p.msgs);
    }

    let mut payload = Vec::new();
    section(&mut payload, SEC_VALUES, &values);
    section(&mut payload, SEC_MESSAGES, &msgs);
    section(&mut payload, SEC_SCHEDULE, &spec.schedule);

    let mut header = [0u8; HEADER_BYTES];
    header[0..8].copy_from_slice(MAGIC);
    header[8..12].copy_from_slice(&CKP_VERSION.to_le_bytes());
    header[12..16].copy_from_slice(&superstep.to_le_bytes());
    header[16..20].copy_from_slice(&spec.meta.pass.to_le_bytes());
    header[20..24].copy_from_slice(&spec.meta.round.to_le_bytes());
    header[24..28].copy_from_slice(&spec.meta.rounds.to_le_bytes());
    header[28..32].copy_from_slice(&n.to_le_bytes());
    header[32..40].copy_from_slice(&spec.fingerprint.to_le_bytes());
    header[40..48].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    header[48..56].copy_from_slice(&fxhash64(&payload).to_le_bytes());
    let sum = fxhash64(&header[..56]);
    header[56..64].copy_from_slice(&sum.to_le_bytes());

    let name = format!(
        "ckpt-{:06}-{:06}.{}",
        spec.meta.unit_seq, superstep, CKP_EXTENSION
    );
    let path = spec.dir.join(&name);
    let tmp = spec.dir.join(format!("{name}.tmp"));

    let res: io::Result<()> = (|| {
        let f = failpoints::retry_io("checkpoint.write", || {
            let mut f = File::create(&tmp)?;
            f.write_all(&header)?;
            f.write_all(&payload)?;
            Ok(f)
        })?;
        failpoints::retry_io("checkpoint.sync", || f.sync_all())?;
        drop(f);
        failpoints::retry_io("checkpoint.rename", || fs::rename(&tmp, &path))
    })();
    if let Err(e) = res {
        let _ = fs::remove_file(&tmp);
        return Err(StoreError::io(format!("write checkpoint {}", path.display()), e));
    }

    if !spec.keep_all {
        let files = checkpoint_files(&spec.dir);
        for stale in files.iter().rev().skip(2) {
            let _ = fs::remove_file(stale);
        }
    }
    Ok(path)
}

/// Checkpoint files in `dir`, sorted ascending by logical order (the
/// zero-padded `ckpt-<unit>-<superstep>` name makes lexicographic order
/// logical order). Empty when the directory is missing or unreadable.
pub fn checkpoint_files(dir: &Path) -> Vec<PathBuf> {
    let Ok(entries) = fs::read_dir(dir) else {
        return Vec::new();
    };
    let mut files: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.extension().is_some_and(|x| x == CKP_EXTENSION)
                && p.file_name()
                    .and_then(|f| f.to_str())
                    .is_some_and(|f| f.starts_with("ckpt-"))
        })
        .collect();
    files.sort();
    files
}

/// A validated, parsed checkpoint file.
pub struct Checkpoint {
    pub path: PathBuf,
    /// The next superstep the resumed run executes.
    pub superstep: u32,
    pub meta: CheckpointMeta,
    pub n: u32,
    pub fingerprint: u64,
    pub schedule: ScheduleState,
    value_count: u64,
    values_raw: Vec<u8>,
    msg_count: u64,
    msgs_raw: Vec<u8>,
}

fn le_u32(b: &[u8]) -> u32 {
    let mut x = [0u8; 4];
    x.copy_from_slice(&b[..4]);
    u32::from_le_bytes(x)
}

fn le_u64(b: &[u8]) -> u64 {
    let mut x = [0u8; 8];
    x.copy_from_slice(&b[..8]);
    u64::from_le_bytes(x)
}

/// Read and validate a checkpoint file. `max_supersteps` bounds the stored
/// superstep (a value beyond the engine's cap is stale or corrupt).
/// Validation order: magic → version → checksum → superstep → size →
/// payload — each failure a typed [`StoreError`] naming the field.
pub fn read_checkpoint(path: &Path, max_supersteps: u32) -> Result<Checkpoint, StoreError> {
    let bytes = fs::read(path)
        .map_err(|e| StoreError::io(format!("read checkpoint {}", path.display()), e))?;
    if bytes.len() < HEADER_BYTES {
        return Err(StoreError::format(
            path,
            "size",
            format!(
                "file has {} bytes, header alone is {HEADER_BYTES}",
                bytes.len()
            ),
        ));
    }
    let header = &bytes[..HEADER_BYTES];
    if &header[0..8] != MAGIC {
        return Err(StoreError::format(
            path,
            "magic",
            "not an FN2VCKP1 checkpoint",
        ));
    }
    let version = le_u32(&header[8..12]);
    if version != CKP_VERSION {
        return Err(StoreError::format(
            path,
            "version",
            format!("version {version}, this build reads {CKP_VERSION}"),
        ));
    }
    let stored_sum = le_u64(&header[56..64]);
    let computed = fxhash64(&header[..56]);
    if stored_sum != computed {
        return Err(StoreError::format(
            path,
            "checksum",
            format!("stored {stored_sum:#x}, computed {computed:#x}"),
        ));
    }
    let superstep = le_u32(&header[12..16]);
    if superstep > max_supersteps {
        return Err(StoreError::format(
            path,
            "superstep",
            format!("superstep {superstep} exceeds the engine cap {max_supersteps} — stale"),
        ));
    }
    let meta = CheckpointMeta {
        pass: le_u32(&header[16..20]),
        round: le_u32(&header[20..24]),
        rounds: le_u32(&header[24..28]),
        unit_seq: 0, // derived from the schedule below
    };
    let n = le_u32(&header[28..32]);
    let fingerprint = le_u64(&header[32..40]);
    let payload_len = le_u64(&header[40..48]);
    let actual = (bytes.len() - HEADER_BYTES) as u64;
    if payload_len != actual {
        return Err(StoreError::format(
            path,
            "size",
            format!("payload needs {payload_len} bytes, file carries {actual}"),
        ));
    }
    let payload = &bytes[HEADER_BYTES..];
    let stored_payload_sum = le_u64(&header[48..56]);
    let computed_payload = fxhash64(payload);
    if stored_payload_sum != computed_payload {
        return Err(StoreError::format(
            path,
            "payload",
            format!("stored {stored_payload_sum:#x}, computed {computed_payload:#x}"),
        ));
    }

    let bad = |d: String| StoreError::format(path, "sections", d);
    let mut r = ByteReader::new(payload);
    let (mut values_raw, mut msgs_raw, mut schedule) = (None, None, None);
    while !r.is_empty() {
        let tag = r.u32().map_err(&bad)?;
        let len = r.u64().map_err(&bad)? as usize;
        let body = r.take(len).map_err(&bad)?;
        match tag {
            SEC_VALUES => values_raw = Some(body),
            SEC_MESSAGES => msgs_raw = Some(body),
            SEC_SCHEDULE => schedule = Some(body),
            other => return Err(bad(format!("unknown section tag {other}"))),
        }
    }
    let (Some(values_raw), Some(msgs_raw), Some(schedule)) = (values_raw, msgs_raw, schedule)
    else {
        return Err(bad("missing a required section".to_string()));
    };
    let schedule = {
        let mut sr = ByteReader::new(schedule);
        decode_schedule(&mut sr).map_err(|d| StoreError::format(path, "schedule", d))?
    };
    let mut vr = ByteReader::new(values_raw);
    let value_count = vr
        .u64()
        .map_err(|d| StoreError::format(path, "values", d))?;
    let mut mr = ByteReader::new(msgs_raw);
    let msg_count = mr
        .u64()
        .map_err(|d| StoreError::format(path, "messages", d))?;
    let meta = CheckpointMeta {
        unit_seq: schedule.done.len() as u32,
        ..meta
    };
    Ok(Checkpoint {
        path: path.to_path_buf(),
        superstep,
        meta,
        n,
        fingerprint,
        schedule,
        value_count,
        values_raw: values_raw[8..].to_vec(),
        msg_count,
        msgs_raw: msgs_raw[8..].to_vec(),
    })
}

/// Newest checkpoint in `dir` that validates and matches `fingerprint`;
/// corrupt or mismatched files are skipped with a warning so one damaged
/// checkpoint falls back to its predecessor instead of failing resume.
pub fn latest_valid(dir: &Path, max_supersteps: u32, fingerprint: u64) -> Option<Checkpoint> {
    for path in checkpoint_files(dir).into_iter().rev() {
        match read_checkpoint(&path, max_supersteps) {
            Ok(c) if c.fingerprint == fingerprint => return Some(c),
            Ok(c) => crate::log_warn!(
                "skipping {}: fingerprint {:#x} does not match this session ({:#x})",
                path.display(),
                c.fingerprint,
                fingerprint
            ),
            Err(e) => crate::log_warn!("skipping corrupt checkpoint: {e}"),
        }
    }
    None
}

impl Checkpoint {
    /// Reconstruct dense engine state. Fails (field `"values"` /
    /// `"messages"`) when the sections do not cover every vertex exactly
    /// once or reference out-of-range ids.
    pub fn snapshot<P: VertexProgram>(&self) -> Result<EngineSnapshot<P>, StoreError>
    where
        P::Value: Persist,
        P::Msg: Persist,
    {
        let n = self.n as usize;
        if self.value_count != self.n as u64 {
            return Err(StoreError::format(
                &self.path,
                "values",
                format!("{} value entries for {} vertices", self.value_count, self.n),
            ));
        }
        let mut values: Vec<Option<P::Value>> = Vec::new();
        values.resize_with(n, || None);
        let mut halted = vec![false; n];
        let mut r = ByteReader::new(&self.values_raw);
        for _ in 0..self.value_count {
            let err = |d: String| StoreError::format(&self.path, "values", d);
            let vid = r.u32().map_err(err)?;
            let h = r.u8().map_err(err)? != 0;
            let v = P::Value::restore(&mut r).map_err(err)?;
            let slot = values
                .get_mut(vid as usize)
                .ok_or_else(|| err(format!("vertex {vid} out of range (n = {n})")))?;
            if slot.is_some() {
                return Err(err(format!("vertex {vid} appears twice")));
            }
            *slot = Some(v);
            halted[vid as usize] = h;
        }
        if !r.is_empty() {
            return Err(StoreError::format(
                &self.path,
                "values",
                format!("{} trailing bytes", r.remaining()),
            ));
        }
        let values: Vec<P::Value> = values
            .into_iter()
            .map(|v| v.unwrap_or_default()) // every slot verified Some above
            .collect();

        let mut messages = Vec::with_capacity(self.msg_count.min(1 << 20) as usize);
        let mut r = ByteReader::new(&self.msgs_raw);
        for _ in 0..self.msg_count {
            let err = |d: String| StoreError::format(&self.path, "messages", d);
            let dst = r.u32().map_err(err)?;
            if dst as usize >= n {
                return Err(err(format!("destination {dst} out of range (n = {n})")));
            }
            let msg = P::Msg::restore(&mut r).map_err(err)?;
            messages.push((dst, msg));
        }
        if !r.is_empty() {
            return Err(StoreError::format(
                &self.path,
                "messages",
                format!("{} trailing bytes", r.remaining()),
            ));
        }
        Ok(EngineSnapshot {
            superstep: self.superstep,
            values,
            halted,
            messages,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fn2v-ckpt-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn byte_reader_bounds_checked() {
        let mut r = ByteReader::new(&[1, 0, 0, 0, 2]);
        assert_eq!(r.u32().unwrap(), 1);
        assert_eq!(r.u8().unwrap(), 2);
        assert!(r.is_empty());
        assert!(r.u32().is_err());
    }

    #[test]
    fn schedule_roundtrips() {
        let s = ScheduleState {
            done: vec![
                UnitId {
                    pass: 0,
                    er: 0,
                    er_count: 4,
                },
                UnitId {
                    pass: 0,
                    er: 1,
                    er_count: 4,
                },
            ],
            queue: vec![(3, 4)],
            sink_blob: Some(vec![9, 8, 7]),
        };
        let enc = encode_schedule(&s);
        let got = decode_schedule(&mut ByteReader::new(&enc)).unwrap();
        assert_eq!(got.done, s.done);
        assert_eq!(got.queue, s.queue);
        assert_eq!(got.sink_blob, s.sink_blob);
    }

    fn demo_parts() -> Vec<EncodedPart> {
        // Two workers, 3 vertices total, values are u64, messages u32.
        let mut a = EncodedPart::default();
        for (vid, val, halted) in [(0u32, 10u64, false), (2, 30, true)] {
            a.values.extend_from_slice(&vid.to_le_bytes());
            a.values.push(halted as u8);
            val.persist(&mut a.values);
            a.value_count += 1;
        }
        a.msgs.extend_from_slice(&1u32.to_le_bytes());
        77u32.persist(&mut a.msgs);
        a.msg_count = 1;
        let mut b = EncodedPart::default();
        b.values.extend_from_slice(&1u32.to_le_bytes());
        b.values.push(0);
        20u64.persist(&mut b.values);
        b.value_count = 1;
        vec![a, b]
    }

    struct DemoProgram;
    impl VertexProgram for DemoProgram {
        type Value = u64;
        type Msg = u32;
        fn compute(
            &self,
            _ctx: &mut crate::pregel::Ctx<'_, Self>,
            _vid: VertexId,
            _value: &mut u64,
            _msgs: &mut Vec<u32>,
        ) {
        }
    }
    impl crate::pregel::Message for u32 {
        fn wire_bytes(&self) -> u64 {
            4
        }
    }

    #[test]
    fn write_read_snapshot_roundtrip() {
        let dir = tmpdir("roundtrip");
        let mut spec = CheckpointSpec::new(&dir, 1);
        spec.fingerprint = 0xFEED;
        spec.meta = CheckpointMeta {
            pass: 1,
            round: 2,
            rounds: 4,
            unit_seq: 6,
        };
        spec.schedule = encode_schedule(&ScheduleState {
            done: vec![UnitId {
                pass: 0,
                er: 0,
                er_count: 1,
            }],
            queue: vec![(3, 4)],
            sink_blob: None,
        });
        let path = write_checkpoint(&spec, 7, 3, demo_parts()).unwrap();
        assert!(path.ends_with(format!("ckpt-000006-000007.{CKP_EXTENSION}")));

        let c = read_checkpoint(&path, 10_000).unwrap();
        assert_eq!(c.superstep, 7);
        assert_eq!(c.n, 3);
        assert_eq!(c.fingerprint, 0xFEED);
        assert_eq!((c.meta.pass, c.meta.round, c.meta.rounds), (1, 2, 4));
        assert_eq!(c.meta.unit_seq, 1); // derived from schedule.done
        assert_eq!(c.schedule.queue, vec![(3, 4)]);

        let snap = c.snapshot::<DemoProgram>().unwrap();
        assert_eq!(snap.superstep, 7);
        assert_eq!(snap.values, vec![10, 20, 30]);
        assert_eq!(snap.halted, vec![false, false, true]);
        assert_eq!(snap.messages, vec![(1, 77)]);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn no_tmp_file_survives_a_write() {
        let dir = tmpdir("atomic");
        write_checkpoint(&CheckpointSpec::new(&dir, 1), 1, 0, vec![]).unwrap();
        let leftovers: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pruning_keeps_the_newest_two() {
        let dir = tmpdir("prune");
        let mut spec = CheckpointSpec::new(&dir, 1);
        for seq in 0..5u32 {
            spec.meta.unit_seq = seq;
            write_checkpoint(&spec, seq, 0, vec![]).unwrap();
        }
        let files = checkpoint_files(&dir);
        assert_eq!(files.len(), 2);
        assert!(files[1].ends_with(format!("ckpt-000004-000004.{CKP_EXTENSION}")));

        spec.keep_all = true;
        for seq in 5..8u32 {
            spec.meta.unit_seq = seq;
            write_checkpoint(&spec, seq, 0, vec![]).unwrap();
        }
        assert_eq!(checkpoint_files(&dir).len(), 5);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_superstep_is_rejected() {
        let dir = tmpdir("stale");
        let path = write_checkpoint(&CheckpointSpec::new(&dir, 1), 50, 0, vec![]).unwrap();
        let err = read_checkpoint(&path, 10).unwrap_err();
        assert_eq!(err.field(), Some("superstep"));
        assert!(read_checkpoint(&path, 50).is_ok());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_valid_skips_corrupt_and_mismatched() {
        let dir = tmpdir("latest");
        let mut spec = CheckpointSpec::new(&dir, 1);
        spec.keep_all = true;
        spec.fingerprint = 0xA;
        spec.meta.unit_seq = 0;
        write_checkpoint(&spec, 1, 0, vec![]).unwrap();
        spec.meta.unit_seq = 1;
        let good = write_checkpoint(&spec, 2, 0, vec![]).unwrap();
        spec.fingerprint = 0xB; // a different session's file
        spec.meta.unit_seq = 2;
        write_checkpoint(&spec, 3, 0, vec![]).unwrap();
        spec.fingerprint = 0xA;
        spec.meta.unit_seq = 3;
        let newest = write_checkpoint(&spec, 4, 0, vec![]).unwrap();
        // Corrupt the newest matching file: flip a payload byte.
        let mut bytes = fs::read(&newest).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        fs::write(&newest, bytes).unwrap();

        let c = latest_valid(&dir, 10_000, 0xA).expect("a valid checkpoint exists");
        assert_eq!(c.path, good);
        assert_eq!(c.superstep, 2);
        assert!(latest_valid(&dir, 10_000, 0xC).is_none());
        fs::remove_dir_all(&dir).ok();
    }
}
