//! A GraphLite-like Pregel engine (Malewicz et al., 2010; Niu & Chen, 2015).
//!
//! The paper runs Fast-Node2Vec on GraphLite, a C/C++ Pregel: a master plus
//! workers connected by a data-center network, executing vertex-centric
//! `compute()` in bulk-synchronous supersteps with in-memory message
//! passing. This module reproduces that machine *in process*: workers are
//! OS threads, the "network" is per-worker inboxes, and the worker boundary
//! is enforced by the API (a vertex may only read adjacency of vertices in
//! its own partition — remote information must travel in messages), so the
//! paper's FN-Local / FN-Cache / FN-Switch optimizations exercise the same
//! code paths they would across real machines. Message volume is accounted
//! in *wire bytes* per superstep, which is what the paper's Figures 4 and 14
//! plot. See DESIGN.md §Substitutions.
//!
//! Extensions the paper made to GraphLite, reproduced here:
//! - an API for a vertex to visit another **same-worker** vertex's edges
//!   ([`Ctx::local_neighbors`], used by FN-Local);
//! - an API to look up the worker that owns any vertex
//!   ([`Ctx::worker_of`], used by FN-Cache);
//! - a per-worker global cache for remote adjacency
//!   ([`Ctx::cache_get`] / [`Ctx::cache_put`], used by FN-Cache).

pub mod checkpoint;
mod engine;
mod metrics;
pub mod transport;

pub use checkpoint::{
    Checkpoint, CheckpointMeta, CheckpointSpec, EngineSnapshot, Persist, ScheduleState, UnitId,
};
pub use engine::{Ctx, Engine, EngineError, EngineOpts, RunResult, VertexProgram, WorkerPlan};
pub use metrics::{EngineMetrics, SuperstepMetrics};
pub use transport::{ChaosConfig, ChaosTransport, Frame, FrameError, FrameKind, Transport, WireMsg};

/// Messages must report their simulated wire size; the engine charges it to
/// the per-superstep accounting that reproduces the paper's Figures 4/14.
pub trait Message: Send {
    fn wire_bytes(&self) -> u64;
}
