//! The L3 coordinator: shard-per-process orchestration of the Pregel walk
//! engine.
//!
//! The paper's GraphLite deployment is a master process plus worker
//! processes on a cluster. PRs 1–6 reproduced the workers *in process*
//! (threads over shared inboxes); this module adds the master: each shard
//! is a separate engine instance — an OS thread over an in-process channel
//! ([`TransportKind::InProc`]) or a spawned child process over a
//! Unix-domain socket ([`TransportKind::Uds`]) — owning a contiguous slice
//! of the global worker space, and the coordinator runs the barrier
//! protocol between them:
//!
//! 1. **Registration** — every shard connects and sends a `Hello` carrying
//!    its graph shape, which must match the coordinator's (a shard that
//!    opened a different file is a deployment error, caught at launch).
//! 2. **Supersteps** — shards exchange cross-shard walk messages as
//!    `Data` frames routed *through* the coordinator (hub and spoke, like
//!    GraphLite's master-mediated control plane), then each sends a
//!    `Barrier` report. Once all reports are in, the coordinator plays
//!    master: aggregate the accounting, check the memory budget, and
//!    broadcast one [`Decision`] — in the same order as the in-process
//!    leader (OOM, quiescence, superstep cap, checkpoint cadence).
//! 3. **Budget accounting** — each shard is charged its share of the graph
//!    ([`shard_shares`]) plus its reported value/message/cache bytes; the
//!    coordinator sums the shares against the *aggregate* budget using the
//!    simulated (`wire_bytes`) sizes, so OOM and FN-Multi degradation
//!    decisions are bit-identical to a single-process run. The *measured*
//!    encoded frame sizes are reported separately as `bytes_remote`.
//! 4. **Checkpoint orchestration** — on a checkpoint superstep every shard
//!    ships its encoded part; the coordinator assembles them into one
//!    FN2VCKP1 file (indistinguishable from an in-process checkpoint, so
//!    `WalkSession::resume` works across shard counts and transports) and
//!    broadcasts the verdict.
//!
//! The coordinator is also a **supervisor**. Failure of any shard — a
//! worker panic surfacing as an `Error` frame, a dead process closing its
//! socket, a poisoned frame stream (sequence or checksum mismatch), or a
//! missed liveness deadline — no longer ends the query. Shards pump
//! `Heartbeat` frames over their connections; the coordinator tracks a
//! per-shard last-seen clock and, while it is *waiting on* a shard,
//! enforces [`DistConfig::liveness_timeout`] against it (heartbeats keep a
//! slow shard alive but deliberately do not reset the useful-frame
//! [`DistConfig::frame_timeout`], so a wedged-but-alive fleet still
//! fails over). On failure the coordinator aborts the unit, tears the
//! whole fleet down, respawns it as a new *generation* (stale frames from
//! the old fleet carry the old generation tag and are dropped), rehydrates
//! from the newest FN2VCKP1 checkpoint of the *same unit* when one exists,
//! and replays. [`DistConfig::restart_budget`] bounds the loop with capped
//! exponential backoff between attempts; exhausting it surfaces the
//! original typed [`EngineError::ShardFailed`]. Walks are bit-identical
//! across any kill/respawn schedule because replay is deterministic
//! (counter-based RNG) and checkpoints cut on superstep boundaries.
//!
//! The deterministic-chaos decorator ([`ChaosTransport`]) wraps every
//! shard connection when [`DistConfig::chaos`] is set: the coordinator
//! wraps its writer half of each connection (coordinator → shard) and the
//! shard wraps its whole connection (shard → coordinator), so each
//! direction runs one seeded fault schedule. The chaos soak tests drive
//! kill-and-respawn cycles through exactly this supervision path.

use std::io;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::process::{Child, Command};
use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::util::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use crate::util::sync::{Arc, Mutex};
use crate::util::sync::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::graph::partition::Partitioner;
use crate::graph::{open_graph, Graph, OpenOptions, VertexId};
use crate::node2vec::program::FnValue;
use crate::node2vec::session::SeedSet;
use crate::node2vec::{FnConfig, FnMsg, FnProgram, SamplerKind, Variant, WalkStats};
use crate::pregel::checkpoint::{
    self, ByteReader, CheckpointSpec, EncodedPart, EngineSnapshot, Persist,
};
use crate::pregel::transport::{
    decode_walk_delta, encode_walk_delta, ChanTransport, ChaosConfig, ChaosTransport, Decision,
    Frame, FrameKind, ShardReport, UdsTransport, CHAOS_DIR_TO_COORD, CHAOS_DIR_TO_SHARD, COORD_ID,
};
use crate::pregel::{
    Engine, EngineError, EngineMetrics, EngineOpts, FrameError, RunResult, SuperstepMetrics,
    Transport, WorkerPlan,
};

/// Upper bound on the shard count (`u8::MAX` is the coordinator's id in
/// frame headers, and nobody needs more than 64 processes on one box).
pub const MAX_SHARDS: usize = 64;

/// Environment variable carrying the fleet generation to spawned shard
/// processes (0 for the first launch, +1 per respawn). Failpoint specs are
/// generation-scoped so a respawned shard does not deterministically
/// re-die on the fault that killed its predecessor.
pub const SHARD_GENERATION_ENV: &str = "FASTN2V_SHARD_GENERATION";

/// Which transport shard connections use (the `--transport` knob).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// Shards are threads in this process; frames cross an in-memory
    /// channel but run the full codec (checksums included).
    #[default]
    InProc,
    /// Shards are child processes; frames cross Unix-domain sockets.
    Uds,
}

impl TransportKind {
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Uds => "uds",
        }
    }

    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "inproc" => Some(TransportKind::InProc),
            "uds" => Some(TransportKind::Uds),
            _ => None,
        }
    }
}

/// Shard-per-process deployment shape (the `--shards` / `--transport`
/// knobs). `shards × workers_per_shard` is the global worker space, and
/// walks are bit-identical across every (shards, transport) choice.
#[derive(Clone, Debug)]
pub struct DistConfig {
    pub shards: usize,
    pub workers_per_shard: usize,
    pub transport: TransportKind,
    /// Binary spawned as `shard-worker` under [`TransportKind::Uds`];
    /// defaults to the current executable.
    pub shard_binary: Option<PathBuf>,
    /// FN2VGRF2 file shard processes open. `None` makes the coordinator
    /// spill the in-memory graph to a temp file for the query's lifetime.
    pub graph_file: Option<PathBuf>,
    /// Shard processes map the graph file instead of owned-loading it.
    pub mmap: bool,
    /// Extra environment for spawned shard processes (the kill-recovery
    /// tests arm a failpoint in one specific shard this way).
    pub shard_env: Vec<(String, String)>,
    /// How long the coordinator waits for *any* useful shard frame before
    /// declaring the fleet wedged and failing the attempt. Heartbeats do
    /// not reset this clock — a fleet that is alive but making no progress
    /// still fails over (the `--frame-timeout` knob).
    pub frame_timeout: Duration,
    /// How long spawned shard processes get to connect back (the
    /// `--accept-timeout` knob).
    pub accept_timeout: Duration,
    /// How long shutdown waits for a shard process to exit before killing
    /// it (the `--reap-timeout` knob).
    pub reap_timeout: Duration,
    /// Cadence of shard `Heartbeat` frames (the `--heartbeat-ms` knob).
    pub heartbeat_interval: Duration,
    /// A shard the coordinator is waiting on that has been silent — no
    /// frame of *any* kind, heartbeats included — for this long is
    /// declared dead and the fleet is respawned (the `--liveness-ms`
    /// knob). Must comfortably exceed `heartbeat_interval`.
    pub liveness_timeout: Duration,
    /// Fleet respawns the supervisor attempts per unit before giving up
    /// with a typed `ShardFailed` (the `--restart-budget` knob; 0 restores
    /// the pre-supervision fail-fast behavior).
    pub restart_budget: u32,
    /// Backoff before the first respawn; doubles per consecutive failure.
    pub backoff_base: Duration,
    /// Upper bound on the respawn backoff.
    pub backoff_cap: Duration,
    /// Deterministic fault injection on every shard connection (soak
    /// tests); `None` in production.
    pub chaos: Option<ChaosConfig>,
}

impl DistConfig {
    pub fn new(shards: usize, workers_per_shard: usize) -> DistConfig {
        DistConfig {
            shards,
            workers_per_shard,
            transport: TransportKind::InProc,
            shard_binary: None,
            graph_file: None,
            mmap: false,
            shard_env: Vec::new(),
            frame_timeout: Duration::from_secs(120),
            accept_timeout: Duration::from_secs(60),
            reap_timeout: Duration::from_secs(5),
            heartbeat_interval: Duration::from_secs(2),
            liveness_timeout: Duration::from_secs(15),
            restart_budget: 3,
            backoff_base: Duration::from_millis(200),
            backoff_cap: Duration::from_secs(5),
            chaos: None,
        }
    }

    pub fn with_transport(mut self, t: TransportKind) -> Self {
        self.transport = t;
        self
    }

    pub fn with_shard_binary(mut self, p: impl Into<PathBuf>) -> Self {
        self.shard_binary = Some(p.into());
        self
    }

    pub fn with_graph_file(mut self, p: impl Into<PathBuf>) -> Self {
        self.graph_file = Some(p.into());
        self
    }

    pub fn with_mmap(mut self, yes: bool) -> Self {
        self.mmap = yes;
        self
    }

    pub fn with_shard_env(mut self, key: impl Into<String>, val: impl Into<String>) -> Self {
        self.shard_env.push((key.into(), val.into()));
        self
    }

    pub fn with_frame_timeout(mut self, t: Duration) -> Self {
        self.frame_timeout = t;
        self
    }

    pub fn with_accept_timeout(mut self, t: Duration) -> Self {
        self.accept_timeout = t;
        self
    }

    pub fn with_reap_timeout(mut self, t: Duration) -> Self {
        self.reap_timeout = t;
        self
    }

    pub fn with_heartbeat_interval(mut self, t: Duration) -> Self {
        self.heartbeat_interval = t;
        self
    }

    pub fn with_liveness_timeout(mut self, t: Duration) -> Self {
        self.liveness_timeout = t;
        self
    }

    pub fn with_restart_budget(mut self, budget: u32) -> Self {
        self.restart_budget = budget;
        self
    }

    pub fn with_backoff(mut self, base: Duration, cap: Duration) -> Self {
        self.backoff_base = base;
        self.backoff_cap = cap;
        self
    }

    pub fn with_chaos(mut self, chaos: ChaosConfig) -> Self {
        self.chaos = Some(chaos);
        self
    }
}

/// What a connection pump thread reports to the coordinator's event loop.
enum Event {
    /// A non-`Data` frame from this shard (`Data` is forwarded straight to
    /// its destination's write queue, never surfacing here).
    Frame(Frame),
    /// The connection died: clean close, transport error, or a write to
    /// the shard failed. The detail is human-readable.
    Closed(String),
}

/// Everything a shard needs to execute one engine unit, carried in a
/// `Run` frame (the coordinator encodes, [`shard_serve`] decodes).
pub(crate) struct UnitSpec {
    pub cfg: FnConfig,
    pub opts: EngineOpts,
    /// Global worker count (`shards × workers_per_shard`).
    pub workers: usize,
    pub er: u32,
    pub er_count: u32,
    pub seeds: SeedSet,
    /// Checkpoint phases are on: the coordinator owns the cadence and the
    /// file; shards only ship encoded parts.
    pub ckpt_active: bool,
    pub resume: Option<SnapshotWire>,
}

/// An [`EngineSnapshot`] flattened for the `Run` frame, in exactly the
/// checkpoint-section entry format.
pub(crate) struct SnapshotWire {
    pub superstep: u32,
    pub value_count: u64,
    pub values: Vec<u8>,
    pub msg_count: u64,
    pub msgs: Vec<u8>,
}

/// Per-unit inputs to [`Coordinator::run_unit`] — the sharded analogue of
/// one `Engine::run_on*` call in the session driver.
pub(crate) struct UnitParams<'a> {
    pub cfg: FnConfig,
    pub opts: EngineOpts,
    pub er: u32,
    pub er_count: u32,
    pub seeds: &'a SeedSet,
    pub ckpt: Option<&'a CheckpointSpec>,
    pub resume: Option<EngineSnapshot<FnProgram>>,
}

type TransportHalves = (Box<dyn Transport>, Box<dyn Transport>);

/// The per-query master. Launching starts the shard fleet and completes
/// registration; [`Coordinator::run_unit`] then serves any number of
/// engine units (FN-Multi rounds, degradation splits) over the same
/// fleet; dropping it shuts the fleet down.
pub struct Coordinator {
    /// Deployment shape, kept so the supervisor can respawn the fleet.
    cfg: DistConfig,
    graph: Arc<Graph>,
    shards: usize,
    wps: usize,
    n: usize,
    /// Per-shard graph-resident budget share; sums exactly to
    /// `graph.resident_bytes()`.
    shares: Vec<u64>,
    writers: Vec<Sender<Frame>>,
    events: Receiver<(usize, u64, Event)>,
    /// Kept so respawned fleets report into the same event queue; events
    /// carry the generation they were produced under and stale ones are
    /// dropped in [`Coordinator::next_frame`].
    event_tx: Sender<(usize, u64, Event)>,
    reader_threads: Vec<JoinHandle<()>>,
    writer_threads: Vec<JoinHandle<()>>,
    serve_threads: Vec<JoinHandle<()>>,
    children: Vec<Child>,
    spilled: Option<PathBuf>,
    socket: Option<PathBuf>,
    /// Rendezvous listener, retained across respawns so a new generation
    /// of shard processes can dial the same socket.
    listener: Option<UnixListener>,
    /// Resolved FN2VGRF2 path shard processes open (set on first UDS
    /// launch; either `cfg.graph_file` or the spilled temp file).
    graph_path: Option<PathBuf>,
    /// Fleet generation: 0 for the launch fleet, +1 per respawn.
    generation: u64,
    /// Per-shard last-seen clocks (milliseconds since `epoch`), stored by
    /// the reader threads on every received frame, heartbeats included.
    last_seen: Vec<Arc<AtomicU64>>,
    epoch: Instant,
    respawns: u64,
    heartbeat_misses: u64,
    /// Terminal failure; once set every subsequent unit is refused (the
    /// restart budget was exhausted or a respawn itself failed).
    failed: Option<String>,
}

fn launch_err(detail: String) -> EngineError {
    EngineError::ShardFailed {
        shard: usize::MAX,
        detail,
    }
}

impl Coordinator {
    /// Start the shard fleet described by `dist` and complete the `Hello`
    /// registration handshake. `part` must span
    /// `dist.shards × dist.workers_per_shard` workers.
    pub fn launch(
        graph: &Arc<Graph>,
        part: &Partitioner,
        dist: &DistConfig,
    ) -> Result<Coordinator, EngineError> {
        let (shards, wps) = (dist.shards, dist.workers_per_shard);
        if shards == 0 || shards > MAX_SHARDS {
            return Err(EngineError::Config {
                detail: format!("shard count {shards} outside 1..={MAX_SHARDS}"),
            });
        }
        if wps == 0 {
            return Err(EngineError::Config {
                detail: "workers-per-shard must be at least 1".to_string(),
            });
        }
        if part.num_workers() != shards * wps {
            return Err(EngineError::Config {
                detail: format!(
                    "partitioner spans {} workers, expected {shards} shards × {wps} per shard",
                    part.num_workers()
                ),
            });
        }
        let (event_tx, events) = mpsc::channel();
        // Built incrementally so any launch failure drops a half-built
        // coordinator and `Drop` reaps whatever was already started.
        let mut coord = Coordinator {
            cfg: dist.clone(),
            graph: Arc::clone(graph),
            shards,
            wps,
            n: graph.num_vertices(),
            shares: shard_shares(graph, part, shards, wps),
            writers: Vec::new(),
            events,
            event_tx,
            reader_threads: Vec::new(),
            writer_threads: Vec::new(),
            serve_threads: Vec::new(),
            children: Vec::new(),
            spilled: None,
            socket: None,
            listener: None,
            graph_path: None,
            generation: 0,
            last_seen: Vec::new(),
            epoch: Instant::now(),
            respawns: 0,
            heartbeat_misses: 0,
            failed: None,
        };
        let conns = match dist.transport {
            TransportKind::InProc => coord.launch_inproc()?,
            TransportKind::Uds => {
                coord.prepare_uds()?;
                coord.spawn_and_accept()?
            }
        };
        coord.handshake(conns)?;
        Ok(coord)
    }

    /// Spawn one serve-loop thread per shard over in-process channels.
    /// Callable again after [`Coordinator::teardown_fleet`] to start the
    /// next generation.
    fn launch_inproc(&mut self) -> Result<Vec<Box<dyn Transport>>, EngineError> {
        let shards = self.shards;
        let mut conns: Vec<Box<dyn Transport>> = Vec::with_capacity(shards);
        for s in 0..shards {
            let (coord_end, shard_end) = ChanTransport::pair();
            let mut shard_conn: Box<dyn Transport> = Box::new(shard_end);
            if let Some(chaos) = self.cfg.chaos {
                shard_conn = ChaosTransport::wrap(
                    shard_conn,
                    chaos,
                    s as u8,
                    CHAOS_DIR_TO_COORD,
                    self.generation,
                );
            }
            let g = Arc::clone(&self.graph);
            let heartbeat = self.cfg.heartbeat_interval;
            let handle = crate::util::sync::thread::Builder::new()
                .name(format!("fn2v-shard-{s}"))
                .spawn(move || {
                    let _ = shard_serve(&g, s, shards, shard_conn, heartbeat);
                })
                .map_err(|e| launch_err(format!("spawn shard thread {s}: {e}")))?;
            self.serve_threads.push(handle);
            conns.push(Box::new(coord_end));
        }
        Ok(conns)
    }

    /// One-time UDS setup: spill the graph if needed and bind the
    /// rendezvous socket. The listener is retained for the coordinator's
    /// lifetime so respawned generations can dial the same address.
    fn prepare_uds(&mut self) -> Result<(), EngineError> {
        let graph_path = match &self.cfg.graph_file {
            Some(p) => p.clone(),
            None => {
                let p = crate::graph::store::spill_v2_temp(&self.graph, &std::env::temp_dir())
                    .map_err(|e| launch_err(format!("spill graph for shard processes: {e}")))?;
                self.spilled = Some(p.clone());
                p
            }
        };
        self.graph_path = Some(graph_path);
        static SOCK_SEQ: AtomicU64 = AtomicU64::new(0);
        let sock = std::env::temp_dir().join(format!(
            "fn2v-coord-{}-{}.sock",
            std::process::id(),
            SOCK_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_file(&sock);
        let listener = UnixListener::bind(&sock)
            .map_err(|e| launch_err(format!("bind {}: {e}", sock.display())))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| launch_err(format!("rendezvous socket: {e}")))?;
        self.socket = Some(sock);
        self.listener = Some(listener);
        Ok(())
    }

    /// Spawn one `shard-worker` child per shard (tagged with the current
    /// generation) and accept their connections on the retained listener.
    fn spawn_and_accept(&mut self) -> Result<Vec<Box<dyn Transport>>, EngineError> {
        let shards = self.shards;
        let sock = self.socket.clone().expect("prepare_uds bound the socket");
        let graph_path = self
            .graph_path
            .clone()
            .expect("prepare_uds resolved the graph path");
        let bin = match &self.cfg.shard_binary {
            Some(p) => p.clone(),
            None => std::env::current_exe()
                .map_err(|e| launch_err(format!("locate shard-worker binary: {e}")))?,
        };
        for s in 0..shards {
            let mut cmd = Command::new(&bin);
            cmd.arg("shard-worker")
                .arg("--socket")
                .arg(&sock)
                .arg("--shard")
                .arg(s.to_string())
                .arg("--shards")
                .arg(shards.to_string())
                .arg("--graph-file")
                .arg(&graph_path)
                .arg("--heartbeat-ms")
                .arg(self.cfg.heartbeat_interval.as_millis().to_string());
            if self.cfg.mmap {
                cmd.arg("--mmap");
            }
            if let Some(chaos) = &self.cfg.chaos {
                cmd.arg("--chaos").arg(encode_chaos_arg(chaos));
            }
            cmd.env(SHARD_GENERATION_ENV, self.generation.to_string());
            for (k, v) in &self.cfg.shard_env {
                cmd.env(k, v);
            }
            let child = cmd
                .spawn()
                .map_err(|e| launch_err(format!("spawn shard {s} ({}): {e}", bin.display())))?;
            self.children.push(child);
        }
        let deadline = Instant::now() + self.cfg.accept_timeout;
        let listener = self.listener.as_ref().expect("prepare_uds bound the socket");
        let mut conns: Vec<Box<dyn Transport>> = Vec::with_capacity(shards);
        while conns.len() < shards {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream
                        .set_nonblocking(false)
                        .map_err(|e| launch_err(format!("shard socket: {e}")))?;
                    conns.push(Box::new(UdsTransport::new(stream)));
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    for (s, child) in self.children.iter_mut().enumerate() {
                        if let Ok(Some(status)) = child.try_wait() {
                            return Err(EngineError::ShardFailed {
                                shard: s,
                                detail: format!("shard process exited during startup: {status}"),
                            });
                        }
                    }
                    if Instant::now() >= deadline {
                        return Err(launch_err(
                            "timed out waiting for shard processes to connect".to_string(),
                        ));
                    }
                    crate::util::sync::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(launch_err(format!("accept shard connection: {e}"))),
            }
        }
        Ok(conns)
    }

    /// Receive every shard's `Hello` (connections arrive in arbitrary
    /// order; `src` identifies the shard), validate the graph shape, and
    /// split each connection into pump threads: a reader that stamps the
    /// shard's last-seen clock on every frame, swallows `Heartbeat`s,
    /// forwards `Data` frames straight to the destination shard's write
    /// queue, and surfaces everything else as a generation-tagged
    /// [`Event`]; and a writer draining an unbounded queue (so forwarding
    /// never blocks on a slow peer). When chaos is configured, the writer
    /// half is wrapped so the coordinator → shard direction runs its own
    /// seeded fault schedule.
    fn handshake(&mut self, conns: Vec<Box<dyn Transport>>) -> Result<(), EngineError> {
        let shards = self.shards;
        let arcs = self.graph.num_arcs() as u64;
        let generation = self.generation;
        let mut writers = Vec::with_capacity(shards);
        let mut writer_rx = Vec::with_capacity(shards);
        for _ in 0..shards {
            let (tx, rx) = mpsc::channel::<Frame>();
            writers.push(tx);
            writer_rx.push(Some(rx));
        }
        let mut halves: Vec<Option<TransportHalves>> = (0..shards).map(|_| None).collect();
        for mut conn in conns {
            let hello = conn
                .recv()
                .map_err(|e| launch_err(format!("awaiting shard hello: {e}")))?;
            if hello.kind != FrameKind::Hello {
                return Err(launch_err(format!(
                    "expected hello, got {:?} frame",
                    hello.kind
                )));
            }
            let s = hello.src as usize;
            if s >= shards {
                return Err(launch_err(format!("hello from unknown shard {s}")));
            }
            if halves[s].is_some() {
                return Err(launch_err(format!("duplicate hello from shard {s}")));
            }
            let mut r = ByteReader::new(&hello.payload);
            let shape = (|| Ok::<_, String>((r.u32()?, r.u64()?)))()
                .map_err(|e| launch_err(format!("bad hello payload from shard {s}: {e}")))?;
            if shape.0 as usize != self.n || shape.1 != arcs {
                return Err(EngineError::ShardFailed {
                    shard: s,
                    detail: format!(
                        "shard opened a different graph: {} vertices / {} arcs, \
                         coordinator has {} / {arcs}",
                        shape.0, shape.1, self.n
                    ),
                });
            }
            halves[s] = Some(
                conn.split()
                    .map_err(|e| launch_err(format!("split shard {s} connection: {e}")))?,
            );
        }
        let now_ms = self.epoch.elapsed().as_millis() as u64;
        self.last_seen = (0..shards)
            .map(|_| Arc::new(AtomicU64::new(now_ms)))
            .collect();
        for (s, half) in halves.into_iter().enumerate() {
            let (mut reader, mut writer) = half.expect("every slot filled by a unique hello");
            if let Some(chaos) = self.cfg.chaos {
                writer =
                    ChaosTransport::wrap(writer, chaos, s as u8, CHAOS_DIR_TO_SHARD, generation);
            }
            let rx = writer_rx[s].take().expect("one writer queue per shard");
            let etx = self.event_tx.clone();
            self.writer_threads.push(
                crate::util::sync::thread::Builder::new()
                    .name(format!("fn2v-wr-{s}"))
                    .spawn(move || {
                        while let Ok(f) = rx.recv() {
                            if let Err(e) = writer.send(&f) {
                                let _ = etx.send((
                                    s,
                                    generation,
                                    Event::Closed(format!("write failed: {e}")),
                                ));
                                break;
                            }
                        }
                    })
                    .map_err(|e| launch_err(format!("spawn writer thread: {e}")))?,
            );
            let etx = self.event_tx.clone();
            let fwd: Vec<Sender<Frame>> = writers.clone();
            let seen = Arc::clone(&self.last_seen[s]);
            let epoch = self.epoch;
            self.reader_threads.push(
                crate::util::sync::thread::Builder::new()
                    .name(format!("fn2v-rd-{s}"))
                    .spawn(move || loop {
                        match reader.recv() {
                            Ok(f) => {
                                seen.store(epoch.elapsed().as_millis() as u64, Ordering::Relaxed);
                                if f.kind == FrameKind::Heartbeat {
                                    // Liveness only; never surfaces as an
                                    // event and never resets frame_timeout.
                                    continue;
                                }
                                if f.kind == FrameKind::Data {
                                    let dst = f.dst as usize;
                                    let ok = dst < fwd.len() && fwd[dst].send(f).is_ok();
                                    if !ok {
                                        let detail =
                                            "data frame for unknown or closed shard".to_string();
                                        let _ = etx.send((s, generation, Event::Closed(detail)));
                                        break;
                                    }
                                } else if etx.send((s, generation, Event::Frame(f))).is_err() {
                                    break;
                                }
                            }
                            Err(FrameError::Closed) => {
                                let _ = etx.send((
                                    s,
                                    generation,
                                    Event::Closed("connection closed".to_string()),
                                ));
                                break;
                            }
                            Err(e) => {
                                let _ = etx.send((
                                    s,
                                    generation,
                                    Event::Closed(format!("transport error: {e}")),
                                ));
                                break;
                            }
                        }
                    })
                    .map_err(|e| launch_err(format!("spawn reader thread: {e}")))?,
            );
        }
        self.writers = writers;
        Ok(())
    }

    /// Run one engine unit across the fleet; the distributed analogue of
    /// one `Engine::run_on` / `run_on_checkpointed` / `run_on_resumed`
    /// call, with identical values, stats, and typed errors.
    ///
    /// This is the supervision loop: each attempt runs on the current
    /// fleet generation; a `ShardFailed` attempt (dead process, poisoned
    /// stream, liveness miss) is retried within
    /// [`DistConfig::restart_budget`] after a full-fleet respawn, resuming
    /// from the newest checkpoint this unit wrote. Coordinator-decided
    /// verdicts (OOM, superstep cap, checkpoint write failure) are
    /// deterministic and never retried.
    pub(crate) fn run_unit(
        &mut self,
        mut params: UnitParams<'_>,
    ) -> Result<(RunResult<FnValue>, WalkStats), EngineError> {
        if let Some(detail) = &self.failed {
            return Err(EngineError::ShardFailed {
                shard: usize::MAX,
                detail: detail.clone(),
            });
        }
        let respawns_at_start = self.respawns;
        let misses_at_start = self.heartbeat_misses;
        let io_retries_at_start = crate::util::failpoints::io_retries();
        let mut resume = params.resume.take();
        let mut failures = 0u32;
        loop {
            match self.run_unit_once(&params, resume.as_ref()) {
                Ok((mut out, stats)) => {
                    out.metrics.respawns = self.respawns - respawns_at_start;
                    out.metrics.heartbeat_misses = self.heartbeat_misses - misses_at_start;
                    out.metrics.io_retries =
                        crate::util::failpoints::io_retries().saturating_sub(io_retries_at_start);
                    return Ok((out, stats));
                }
                // The coordinator itself decided these on a healthy fleet;
                // a retry would reach the identical verdict.
                Err(
                    e @ (EngineError::OutOfMemory { .. }
                    | EngineError::DidNotTerminate { .. }
                    | EngineError::Checkpoint { .. }
                    | EngineError::Config { .. }),
                ) => return Err(e),
                Err(EngineError::ShardFailed { shard, detail }) => {
                    if failures >= self.cfg.restart_budget {
                        self.failed = Some(detail.clone());
                        return Err(EngineError::ShardFailed { shard, detail });
                    }
                    failures += 1;
                    crate::log_warn!(
                        "shard {shard} failed ({detail}); respawning fleet \
                         (attempt {failures}/{})",
                        self.cfg.restart_budget
                    );
                    let backoff = self
                        .cfg
                        .backoff_base
                        .saturating_mul(1u32 << (failures - 1).min(16));
                    crate::util::sync::thread::sleep(backoff.min(self.cfg.backoff_cap));
                    // Rehydrate from the newest durable checkpoint *of this
                    // unit*; a file left by an earlier unit must not hijack
                    // the resume. With no usable checkpoint the unit
                    // replays from its original snapshot (or scratch) —
                    // bit-identical either way.
                    if let Some(spec) = params.ckpt {
                        if let Some(c) = checkpoint::latest_valid(
                            &spec.dir,
                            params.opts.max_supersteps,
                            spec.fingerprint,
                        ) {
                            if c.meta.unit_seq == spec.meta.unit_seq {
                                match c.snapshot::<FnProgram>() {
                                    Ok(s) => resume = Some(s),
                                    Err(e) => crate::log_warn!(
                                        "checkpoint rehydration failed ({e}); \
                                         replaying the unit from its start"
                                    ),
                                }
                            }
                        }
                    }
                    self.relaunch_fleet()?;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// One supervised attempt at a unit on the current fleet generation.
    fn run_unit_once(
        &mut self,
        params: &UnitParams<'_>,
        resume: Option<&EngineSnapshot<FnProgram>>,
    ) -> Result<(RunResult<FnValue>, WalkStats), EngineError> {
        let opts = params.opts;
        let ckpt_active = params.ckpt.is_some();
        let start_superstep = resume.map_or(0, |s| s.superstep);
        let spec = UnitSpec {
            cfg: params.cfg,
            opts,
            workers: self.shards * self.wps,
            er: params.er,
            er_count: params.er_count,
            seeds: params.seeds.clone(),
            ckpt_active,
            resume: resume.map(snapshot_to_wire),
        };
        // A fresh attempt starts the liveness clocks from "now" so silence
        // accrued before the broadcast is not charged to the new unit.
        let now_ms = self.epoch.elapsed().as_millis() as u64;
        for seen in &self.last_seen {
            seen.store(now_ms, Ordering::Relaxed);
        }
        self.broadcast(FrameKind::Run, start_superstep, &encode_run(&spec))?;

        let t_run = Instant::now();
        let shares_total: u64 = self.shares.iter().sum();
        let mut superstep = start_superstep;
        let mut steps: Vec<SuperstepMetrics> = Vec::new();
        let mut peak = 0u64;
        let mut checkpoints_written = 0u64;
        let mut checkpoint_secs = 0f64;
        let mut last_value_bytes = 0u64;
        let mut t_step = Instant::now();
        loop {
            let round = self.collect_barrier(superstep)?;
            let mut m = SuperstepMetrics {
                superstep,
                ..Default::default()
            };
            let mut not_halted = 0u64;
            let mut value_bytes = 0u64;
            for rep in &round {
                m.active_vertices += rep.active;
                not_halted += rep.not_halted;
                m.msgs_local += rep.msgs_within;
                m.msgs_remote += rep.msgs_cross;
                m.bytes_local += rep.bytes_within;
                // Measured encoded frame bytes, not the simulated size.
                m.bytes_remote += rep.bytes_cross_wire;
                m.msg_mem_bytes += rep.bytes_within + rep.bytes_cross_sim;
                m.cache_bytes += rep.cache_bytes;
                value_bytes += rep.value_bytes;
                m.hot_split_tasks += rep.hot_tasks;
                m.worker_compute_secs
                    .extend(rep.compute_nanos.iter().map(|&ns| ns as f64 * 1e-9));
                m.worker_msgs_handled
                    .extend(rep.msgs_handled.iter().copied());
            }
            m.wall_secs = t_step.elapsed().as_secs_f64();
            let total_msgs = m.msgs_local + m.msgs_remote;
            // The exact in-process charge: graph + values + simulated
            // message bytes + cache. Shares sum to `resident_bytes()`, so
            // OOM fires at the same superstep as a single-process run.
            let current = shares_total + value_bytes + m.msg_mem_bytes + m.cache_bytes;
            peak = peak.max(current);
            last_value_bytes = value_bytes;
            steps.push(m);

            // The in-process master's decision order: OOM, quiescence,
            // superstep cap, checkpoint cadence.
            let decision = if opts.memory_budget.is_some_and(|b| current > b) {
                Decision::StopOom {
                    superstep,
                    bytes: current,
                }
            } else if total_msgs == 0 && not_halted == 0 {
                Decision::Stop
            } else if superstep + 1 >= opts.max_supersteps {
                Decision::StopCap {
                    supersteps: superstep + 1,
                }
            } else {
                let due = params
                    .ckpt
                    .is_some_and(|s| (superstep + 1) % s.every.max(1) == 0);
                Decision::Continue { checkpoint: due }
            };
            self.broadcast(FrameKind::Decision, superstep, &decision.encode())?;
            match decision {
                Decision::StopOom { superstep, bytes } => {
                    return Err(EngineError::OutOfMemory { superstep, bytes });
                }
                Decision::StopCap { supersteps } => {
                    return Err(EngineError::DidNotTerminate { supersteps });
                }
                Decision::Stop => break,
                Decision::Continue { checkpoint } => {
                    if checkpoint {
                        let spec = params.ckpt.expect("cadence only fires with a spec");
                        let t_ckpt = Instant::now();
                        self.write_fleet_checkpoint(spec, superstep)?;
                        checkpoints_written += 1;
                        checkpoint_secs += t_ckpt.elapsed().as_secs_f64();
                    }
                    superstep += 1;
                    t_step = Instant::now();
                }
                Decision::Abort { .. } => unreachable!("coordinator never decides Abort here"),
            }
        }

        let (values, stats) = self.collect_values()?;
        let metrics = EngineMetrics {
            supersteps: steps,
            base_bytes: shares_total + last_value_bytes,
            wall_secs: t_run.elapsed().as_secs_f64(),
            peak_bytes: peak,
            checkpoints_written,
            checkpoint_secs,
            // Patched by the supervision wrapper with per-unit deltas.
            respawns: 0,
            heartbeat_misses: 0,
            io_retries: 0,
        };
        Ok((RunResult { values, metrics }, stats))
    }

    /// One `Barrier` report from every shard, in shard order.
    fn collect_barrier(&mut self, superstep: u32) -> Result<Vec<ShardReport>, EngineError> {
        let mut reports: Vec<Option<ShardReport>> = (0..self.shards).map(|_| None).collect();
        while reports.iter().any(|r| r.is_none()) {
            let pending: Vec<bool> = reports.iter().map(|r| r.is_none()).collect();
            let (s, frame) = self.next_frame(&pending)?;
            if frame.kind != FrameKind::Barrier {
                let kind = frame.kind;
                return Err(self.abort(s, format!("unexpected {kind:?} frame at the barrier")));
            }
            let rep = match ShardReport::decode(&frame.payload) {
                Ok(r) => r,
                Err(e) => return Err(self.abort(s, format!("bad barrier report: {e}"))),
            };
            if rep.superstep != superstep {
                return Err(self.abort(
                    s,
                    format!(
                        "barrier report for superstep {} while coordinating {superstep}",
                        rep.superstep
                    ),
                ));
            }
            if reports[s].is_some() {
                return Err(self.abort(s, "duplicate barrier report".to_string()));
            }
            reports[s] = Some(rep);
        }
        Ok(reports.into_iter().map(|r| r.expect("filled")).collect())
    }

    /// Collect every shard's `CkptPart`, assemble one FN2VCKP1 file, and
    /// broadcast the verdict. A failed write mirrors the in-process path:
    /// typed [`EngineError::Checkpoint`], no partial file.
    fn write_fleet_checkpoint(
        &mut self,
        spec: &CheckpointSpec,
        superstep: u32,
    ) -> Result<(), EngineError> {
        let mut parts: Vec<Option<EncodedPart>> = (0..self.shards).map(|_| None).collect();
        while parts.iter().any(|p| p.is_none()) {
            let pending: Vec<bool> = parts.iter().map(|p| p.is_none()).collect();
            let (s, frame) = self.next_frame(&pending)?;
            if frame.kind != FrameKind::CkptPart {
                let kind = frame.kind;
                return Err(self.abort(s, format!("unexpected {kind:?} frame, wanted CkptPart")));
            }
            if parts[s].is_some() {
                return Err(self.abort(s, "duplicate checkpoint part".to_string()));
            }
            let part = match decode_ckpt_part(&frame.payload) {
                Ok(p) => p,
                Err(e) => return Err(self.abort(s, format!("bad checkpoint part: {e}"))),
            };
            parts[s] = Some(part);
        }
        let parts: Vec<EncodedPart> = parts.into_iter().map(|p| p.expect("filled")).collect();
        match checkpoint::write_checkpoint(spec, superstep + 1, self.n as u32, parts) {
            Ok(_) => {
                self.broadcast(FrameKind::CkptResult, superstep, &[1u8])?;
                Ok(())
            }
            Err(e) => {
                let detail = e.to_string();
                let mut payload = vec![0u8];
                payload.extend_from_slice(detail.as_bytes());
                self.broadcast(FrameKind::CkptResult, superstep, &payload)?;
                Err(EngineError::Checkpoint { superstep, detail })
            }
        }
    }

    /// Collect every shard's `Values` frame into the dense result.
    fn collect_values(&mut self) -> Result<(Vec<FnValue>, WalkStats), EngineError> {
        let mut values: Vec<FnValue> = Vec::new();
        values.resize_with(self.n, FnValue::default);
        let mut stats = WalkStats::default();
        let mut got = vec![false; self.shards];
        while got.iter().any(|g| !g) {
            let pending: Vec<bool> = got.iter().map(|g| !g).collect();
            let (s, frame) = self.next_frame(&pending)?;
            if frame.kind != FrameKind::Values {
                let kind = frame.kind;
                return Err(self.abort(s, format!("unexpected {kind:?} frame, wanted Values")));
            }
            if got[s] {
                return Err(self.abort(s, "duplicate values frame".to_string()));
            }
            let (shard_stats, walks) = match decode_values(&frame.payload) {
                Ok(v) => v,
                Err(e) => return Err(self.abort(s, format!("bad values frame: {e}"))),
            };
            stats.merge(&shard_stats);
            for (vid, walk) in walks {
                let Some(slot) = values.get_mut(vid as usize) else {
                    return Err(self.abort(s, format!("walk for out-of-range vertex {vid}")));
                };
                slot.walk = walk;
            }
            got[s] = true;
        }
        Ok((values, stats))
    }

    /// Next coordinator-bound frame; connection failures, `Error` frames,
    /// and a pending shard missing its liveness deadline become an
    /// aborted unit (which the supervision loop may then retry). Events
    /// tagged with an older generation are frames still draining out of a
    /// torn-down fleet and are dropped. `pending[s]` marks the shards this
    /// collection phase is still waiting on — only those are held to the
    /// liveness deadline, because a shard that already reported may be
    /// blocked sending heartbeats while it waits for the verdict.
    fn next_frame(&mut self, pending: &[bool]) -> Result<(usize, Frame), EngineError> {
        let deadline = Instant::now() + self.cfg.frame_timeout;
        // Poll often enough to catch a liveness miss promptly without
        // busy-waiting the event queue.
        let poll = (self.cfg.liveness_timeout / 4)
            .clamp(Duration::from_millis(5), Duration::from_millis(250));
        loop {
            match self.events.recv_timeout(poll) {
                Ok((_, generation, _)) if generation != self.generation => continue,
                Ok((s, _, Event::Frame(f))) => {
                    if f.kind == FrameKind::Error {
                        let detail = String::from_utf8_lossy(&f.payload).into_owned();
                        return Err(self.abort(s, detail));
                    }
                    return Ok((s, f));
                }
                Ok((s, _, Event::Closed(detail))) => return Err(self.abort(s, detail)),
                Err(RecvTimeoutError::Timeout) => {
                    let now_ms = self.epoch.elapsed().as_millis() as u64;
                    let limit_ms = self.cfg.liveness_timeout.as_millis() as u64;
                    for (s, &waiting) in pending.iter().enumerate() {
                        let silent_ms =
                            now_ms.saturating_sub(self.last_seen[s].load(Ordering::Relaxed));
                        if waiting && silent_ms > limit_ms {
                            self.heartbeat_misses += 1;
                            return Err(self.abort(
                                s,
                                format!(
                                    "missed liveness deadline: silent for {silent_ms} ms \
                                     while the coordinator waits on it"
                                ),
                            ));
                        }
                    }
                    if Instant::now() >= deadline {
                        return Err(
                            self.abort_coord("timed out waiting for shard frames".to_string())
                        );
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(self.abort_coord("every shard connection is gone".to_string()));
                }
            }
        }
    }

    fn broadcast(
        &mut self,
        kind: FrameKind,
        superstep: u32,
        payload: &[u8],
    ) -> Result<(), EngineError> {
        let mut dead: Option<usize> = None;
        for (s, w) in self.writers.iter().enumerate() {
            let f = Frame::new(kind, COORD_ID, s as u8, superstep, payload.to_vec());
            if w.send(f).is_err() {
                dead = Some(s);
                break;
            }
        }
        match dead {
            Some(s) => Err(self.abort(s, "shard write queue is gone".to_string())),
            None => Ok(()),
        }
    }

    /// Record the first failure, tell surviving shards to abandon the
    /// unit, and build the error for the caller.
    fn abort(&mut self, shard: usize, detail: String) -> EngineError {
        self.poison(&detail);
        EngineError::ShardFailed { shard, detail }
    }

    fn abort_coord(&mut self, detail: String) -> EngineError {
        self.poison(&detail);
        EngineError::ShardFailed {
            shard: usize::MAX,
            detail,
        }
    }

    fn poison(&mut self, detail: &str) {
        if self.failed.is_some() {
            return;
        }
        self.failed = Some(detail.to_string());
        let abort = Decision::Abort {
            detail: detail.to_string(),
        }
        .encode();
        for (s, w) in self.writers.iter().enumerate() {
            let _ = w.send(Frame::new(
                FrameKind::Decision,
                COORD_ID,
                s as u8,
                0,
                abort.clone(),
            ));
        }
    }

    /// Shut the current fleet down: ask every shard to exit, reap child
    /// processes (killing stragglers after `reap_timeout`), and join every
    /// pump thread. The rendezvous listener and spilled graph survive so
    /// [`Coordinator::relaunch_fleet`] can start the next generation.
    fn teardown_fleet(&mut self) {
        for (s, w) in self.writers.iter().enumerate() {
            let _ = w.send(Frame::new(
                FrameKind::Shutdown,
                COORD_ID,
                s as u8,
                0,
                Vec::new(),
            ));
        }
        // Dropping the senders lets writer threads drain and exit once the
        // reader threads (which hold forwarding clones) are gone too.
        self.writers.clear();
        for h in self.serve_threads.drain(..) {
            let _ = h.join();
        }
        let deadline = Instant::now() + self.cfg.reap_timeout;
        for child in &mut self.children {
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        crate::util::sync::thread::sleep(Duration::from_millis(10));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
        self.children.clear();
        for h in self.reader_threads.drain(..) {
            let _ = h.join();
        }
        for h in self.writer_threads.drain(..) {
            let _ = h.join();
        }
    }

    /// Tear the fleet down and start a fresh one — the next generation —
    /// over the same graph and rendezvous socket, re-running the `Hello`
    /// handshake. The respawn itself is a retryable I/O site
    /// (`coordinator.respawn`): a transient fault there is absorbed, a
    /// fatal one fails the unit typed. Any respawn failure is terminal for
    /// this coordinator — subsequent units are refused.
    fn relaunch_fleet(&mut self) -> Result<(), EngineError> {
        if let Err(e) = crate::util::failpoints::retry_io("coordinator.respawn", || Ok(())) {
            let detail = format!("respawning shard fleet: {e}");
            self.failed = Some(detail.clone());
            return Err(launch_err(detail));
        }
        self.teardown_fleet();
        self.failed = None;
        self.generation += 1;
        self.respawns += 1;
        let conns = match self.cfg.transport {
            TransportKind::InProc => self.launch_inproc(),
            TransportKind::Uds => self.spawn_and_accept(),
        };
        let result = conns.and_then(|c| self.handshake(c));
        if let Err(e) = &result {
            self.failed = Some(format!("fleet respawn failed: {e}"));
        }
        result
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.teardown_fleet();
        self.listener = None;
        if let Some(p) = self.socket.take() {
            let _ = std::fs::remove_file(p);
        }
        if let Some(p) = self.spilled.take() {
            let _ = std::fs::remove_file(p);
        }
    }
}

// ---------------------------------------------------------------------------
// Budget shares
// ---------------------------------------------------------------------------

/// Split the graph's resident bytes across shards proportionally to the
/// arcs their workers own. Shard 0 takes the rounding remainder, so the
/// shares always sum *exactly* to `graph.resident_bytes()` — the budget
/// check must charge the same total as a single-process run.
pub fn shard_shares(graph: &Graph, part: &Partitioner, shards: usize, wps: usize) -> Vec<u64> {
    let resident = graph.resident_bytes();
    let mut arcs = vec![0u64; shards];
    for v in 0..graph.num_vertices() {
        let s = part.worker_of(v as VertexId) / wps;
        arcs[s] += graph.degree(v as VertexId) as u64;
    }
    let m: u64 = arcs.iter().sum();
    let mut shares = vec![0u64; shards];
    if shards == 1 || m == 0 {
        shares[0] = resident;
        return shares;
    }
    let mut rest = 0u64;
    for s in 1..shards {
        let share = ((resident as u128 * arcs[s] as u128) / m as u128) as u64;
        shares[s] = share;
        rest += share;
    }
    shares[0] = resident - rest;
    shares
}

// ---------------------------------------------------------------------------
// Frame payload codecs
// ---------------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn variant_index(v: Variant) -> u8 {
    Variant::ALL
        .iter()
        .position(|&x| x == v)
        .expect("every variant is in ALL") as u8
}

fn partitioner_index(k: crate::node2vec::PartitionerKind) -> u8 {
    crate::node2vec::PartitionerKind::ALL
        .iter()
        .position(|&x| x == k)
        .expect("every partitioner kind is in ALL") as u8
}

fn put_opt_u32(out: &mut Vec<u8>, v: Option<u32>) {
    match v {
        Some(x) => {
            out.push(1);
            put_u32(out, x);
        }
        None => out.push(0),
    }
}

fn put_opt_u64(out: &mut Vec<u8>, v: Option<u64>) {
    match v {
        Some(x) => {
            out.push(1);
            put_u64(out, x);
        }
        None => out.push(0),
    }
}

fn get_opt_u32(r: &mut ByteReader<'_>) -> Result<Option<u32>, String> {
    Ok(if r.u8()? != 0 { Some(r.u32()?) } else { None })
}

fn get_opt_u64(r: &mut ByteReader<'_>) -> Result<Option<u64>, String> {
    Ok(if r.u8()? != 0 { Some(r.u64()?) } else { None })
}

/// Encode a `Run` frame payload. The memory budget is deliberately *not*
/// shipped: shards must never make their own OOM decisions — the
/// coordinator owns the aggregate budget.
pub(crate) fn encode_run(spec: &UnitSpec) -> Vec<u8> {
    let mut out = Vec::with_capacity(128);
    let cfg = &spec.cfg;
    put_u32(&mut out, cfg.p.to_bits());
    put_u32(&mut out, cfg.q.to_bits());
    put_u32(&mut out, cfg.walk_length);
    put_u64(&mut out, cfg.seed);
    out.push(variant_index(cfg.variant));
    put_u32(&mut out, cfg.popular_threshold);
    put_u64(&mut out, cfg.approx_eps.to_bits());
    out.push(match cfg.sampler {
        SamplerKind::Linear => 0,
        SamplerKind::Reject => 1,
    });
    out.push(partitioner_index(cfg.partitioner));
    put_opt_u32(&mut out, cfg.hot_threshold);

    put_u32(&mut out, spec.opts.max_supersteps);
    put_opt_u64(&mut out, spec.opts.cache_capacity);
    put_opt_u32(&mut out, spec.opts.hot_degree_threshold);
    out.push(u8::from(spec.opts.strict_memory));
    out.push(u8::from(spec.opts.hot_split_cross_shard));

    put_u32(&mut out, spec.workers as u32);
    put_u32(&mut out, spec.er);
    put_u32(&mut out, spec.er_count);
    match &spec.seeds {
        SeedSet::All => out.push(0),
        SeedSet::Slice { start, end } => {
            out.push(1);
            put_u32(&mut out, *start);
            put_u32(&mut out, *end);
        }
        SeedSet::Explicit(ids) => {
            out.push(2);
            put_u32(&mut out, ids.len() as u32);
            for id in ids {
                put_u32(&mut out, *id);
            }
        }
    }
    out.push(u8::from(spec.ckpt_active));
    match &spec.resume {
        None => out.push(0),
        Some(w) => {
            out.push(1);
            put_u32(&mut out, w.superstep);
            put_u64(&mut out, w.value_count);
            put_u64(&mut out, w.values.len() as u64);
            out.extend_from_slice(&w.values);
            put_u64(&mut out, w.msg_count);
            put_u64(&mut out, w.msgs.len() as u64);
            out.extend_from_slice(&w.msgs);
        }
    }
    out
}

pub(crate) fn decode_run(buf: &[u8]) -> Result<UnitSpec, String> {
    let mut r = ByteReader::new(buf);
    let p = f32::from_bits(r.u32()?);
    let q = f32::from_bits(r.u32()?);
    let walk_length = r.u32()?;
    let seed = r.u64()?;
    let vi = r.u8()? as usize;
    let variant = *Variant::ALL
        .get(vi)
        .ok_or_else(|| format!("bad variant index {vi}"))?;
    let popular_threshold = r.u32()?;
    let approx_eps = f64::from_bits(r.u64()?);
    let sampler = match r.u8()? {
        0 => SamplerKind::Linear,
        1 => SamplerKind::Reject,
        other => return Err(format!("bad sampler tag {other}")),
    };
    let pi = r.u8()? as usize;
    let partitioner = *crate::node2vec::PartitionerKind::ALL
        .get(pi)
        .ok_or_else(|| format!("bad partitioner index {pi}"))?;
    let hot_threshold = get_opt_u32(&mut r)?;
    let cfg = FnConfig {
        p,
        q,
        walk_length,
        seed,
        variant,
        popular_threshold,
        approx_eps,
        sampler,
        partitioner,
        hot_threshold,
    };
    let opts = EngineOpts {
        max_supersteps: r.u32()?,
        memory_budget: None,
        cache_capacity: get_opt_u64(&mut r)?,
        hot_degree_threshold: get_opt_u32(&mut r)?,
        strict_memory: r.u8()? != 0,
        hot_split_cross_shard: r.u8()? != 0,
    };
    let workers = r.u32()? as usize;
    let er = r.u32()?;
    let er_count = r.u32()?;
    let seeds = match r.u8()? {
        0 => SeedSet::All,
        1 => SeedSet::Slice {
            start: r.u32()?,
            end: r.u32()?,
        },
        2 => {
            let count = r.u32()? as usize;
            let mut ids = Vec::with_capacity(count.min(1 << 20));
            for _ in 0..count {
                ids.push(r.u32()?);
            }
            SeedSet::Explicit(ids)
        }
        other => return Err(format!("bad seed-set tag {other}")),
    };
    let ckpt_active = r.u8()? != 0;
    let resume = if r.u8()? != 0 {
        let superstep = r.u32()?;
        let value_count = r.u64()?;
        let vlen = r.u64()? as usize;
        let values = r.take(vlen)?.to_vec();
        let msg_count = r.u64()?;
        let mlen = r.u64()? as usize;
        let msgs = r.take(mlen)?.to_vec();
        Some(SnapshotWire {
            superstep,
            value_count,
            values,
            msg_count,
            msgs,
        })
    } else {
        None
    };
    if !r.is_empty() {
        return Err(format!("{} trailing bytes after run spec", r.remaining()));
    }
    Ok(UnitSpec {
        cfg,
        opts,
        workers,
        er,
        er_count,
        seeds,
        ckpt_active,
        resume,
    })
}

/// Flatten a dense snapshot into checkpoint-section entry format for the
/// `Run` frame (the inverse of [`wire_to_snapshot`]).
pub(crate) fn snapshot_to_wire(snap: &EngineSnapshot<FnProgram>) -> SnapshotWire {
    let mut values = Vec::new();
    for (vid, v) in snap.values.iter().enumerate() {
        (vid as u32).persist(&mut values);
        values.push(u8::from(snap.halted[vid]));
        v.persist(&mut values);
    }
    let mut msgs = Vec::new();
    for (dst, m) in &snap.messages {
        dst.persist(&mut msgs);
        m.persist(&mut msgs);
    }
    SnapshotWire {
        superstep: snap.superstep,
        value_count: snap.values.len() as u64,
        values,
        msg_count: snap.messages.len() as u64,
        msgs,
    }
}

/// Rebuild the dense snapshot a shard resumes from. Every shard decodes
/// the *full* snapshot; the engine delivers only the messages its workers
/// own, so no per-shard slicing happens here.
pub(crate) fn wire_to_snapshot(
    w: &SnapshotWire,
    n: usize,
) -> Result<EngineSnapshot<FnProgram>, String> {
    if w.value_count != n as u64 {
        return Err(format!(
            "snapshot has {} value entries for {n} vertices",
            w.value_count
        ));
    }
    let mut values: Vec<FnValue> = Vec::new();
    values.resize_with(n, FnValue::default);
    let mut halted = vec![false; n];
    let mut r = ByteReader::new(&w.values);
    for _ in 0..w.value_count {
        let vid = r.u32()? as usize;
        let h = r.u8()? != 0;
        let v = FnValue::restore(&mut r)?;
        if vid >= n {
            return Err(format!("snapshot vertex {vid} out of range (n = {n})"));
        }
        values[vid] = v;
        halted[vid] = h;
    }
    if !r.is_empty() {
        return Err(format!("{} trailing snapshot value bytes", r.remaining()));
    }
    let mut messages = Vec::with_capacity(w.msg_count.min(1 << 20) as usize);
    let mut r = ByteReader::new(&w.msgs);
    for _ in 0..w.msg_count {
        let dst = r.u32()?;
        if dst as usize >= n {
            return Err(format!("snapshot message for vertex {dst} out of range"));
        }
        let msg = <FnMsg as Persist>::restore(&mut r)?;
        messages.push((dst, msg));
    }
    if !r.is_empty() {
        return Err(format!("{} trailing snapshot message bytes", r.remaining()));
    }
    Ok(EngineSnapshot {
        superstep: w.superstep,
        values,
        halted,
        messages,
    })
}

/// Decode one shard's `CkptPart` payload (the format the engine's
/// checkpoint phase produces).
fn decode_ckpt_part(buf: &[u8]) -> Result<EncodedPart, String> {
    let mut r = ByteReader::new(buf);
    let value_count = r.u64()?;
    let vlen = r.u64()? as usize;
    let values = r.take(vlen)?.to_vec();
    let msg_count = r.u64()?;
    let mlen = r.u64()? as usize;
    let msgs = r.take(mlen)?.to_vec();
    if !r.is_empty() {
        return Err(format!("{} trailing bytes", r.remaining()));
    }
    Ok(EncodedPart {
        value_count,
        values,
        msg_count,
        msgs,
    })
}

/// Encode a shard's `Values` payload: the 11 [`WalkStats`] counters, then
/// the shard's non-empty walks delta-encoded against each walk's start.
fn encode_values_payload(stats: &WalkStats, walks: &[(VertexId, &Vec<VertexId>)]) -> Vec<u8> {
    let mut out = Vec::with_capacity(96 + walks.len() * 8);
    for v in [
        stats.exact_steps,
        stats.approx_steps,
        stats.local_reads,
        stats.cache_stores,
        stats.cache_hits,
        stats.markers_sent,
        stats.cache_retries,
        stats.switched_hops,
        stats.truncated_walks,
        stats.reject_proposals,
        stats.reject_fallbacks,
    ] {
        put_u64(&mut out, v);
    }
    put_u32(&mut out, walks.len() as u32);
    for (vid, walk) in walks {
        encode_walk_delta(*vid, walk, &mut out);
    }
    out
}

#[allow(clippy::type_complexity)]
fn decode_values(buf: &[u8]) -> Result<(WalkStats, Vec<(VertexId, Vec<VertexId>)>), String> {
    let mut r = ByteReader::new(buf);
    let mut fields = [0u64; 11];
    for f in &mut fields {
        *f = r.u64()?;
    }
    let stats = WalkStats {
        exact_steps: fields[0],
        approx_steps: fields[1],
        local_reads: fields[2],
        cache_stores: fields[3],
        cache_hits: fields[4],
        markers_sent: fields[5],
        cache_retries: fields[6],
        switched_hops: fields[7],
        truncated_walks: fields[8],
        reject_proposals: fields[9],
        reject_fallbacks: fields[10],
        per_round: Vec::new(),
    };
    let count = r.u32()? as usize;
    let mut walks = Vec::with_capacity(count.min(1 << 20));
    for _ in 0..count {
        let vid = r.u32()?;
        let walk = decode_walk_delta(vid, &mut r)?;
        walks.push((vid, walk));
    }
    if !r.is_empty() {
        return Err(format!("{} trailing bytes after values", r.remaining()));
    }
    Ok((stats, walks))
}

// ---------------------------------------------------------------------------
// Shard side
// ---------------------------------------------------------------------------

/// A shard's serve loop: register with a `Hello`, start the heartbeat
/// pump, then execute `Run` units until `Shutdown` (or the coordinator
/// hangs up). Both the in-process shard threads and the `shard-worker`
/// child processes run exactly this.
pub fn shard_serve(
    graph: &Arc<Graph>,
    shard: usize,
    shards: usize,
    mut conn: Box<dyn Transport>,
    heartbeat: Duration,
) -> Result<(), FrameError> {
    let mut hello = Vec::with_capacity(12);
    put_u32(&mut hello, graph.num_vertices() as u32);
    put_u64(&mut hello, graph.num_arcs() as u64);
    conn.send(&Frame::new(
        FrameKind::Hello,
        shard as u8,
        COORD_ID,
        0,
        hello,
    ))?;
    let conn = Arc::new(Mutex::new(conn));
    let stop = Arc::new(AtomicBool::new(false));
    let beats = {
        let conn = Arc::clone(&conn);
        let stop = Arc::clone(&stop);
        crate::util::sync::thread::Builder::new()
            .name(format!("fn2v-hb-{shard}"))
            .spawn(move || heartbeat_loop(&conn, &stop, shard, heartbeat))
            .ok()
    };
    let result = shard_serve_loop(graph, shard, shards, &conn);
    stop.store(true, Ordering::Relaxed);
    if let Some(h) = beats {
        let _ = h.join();
    }
    result
}

/// Send one `Heartbeat` immediately — so a just-launched (or respawned)
/// shard proves liveness before its first barrier, and the
/// `transport.heartbeat` failpoint is exercised deterministically — then
/// one per `interval` until `stop` is set or a send fails (a dead
/// connection is the coordinator's problem to notice, not ours to
/// report). The heartbeat shares the connection mutex with the unit
/// leader, so beats pause exactly while the shard is itself blocked
/// receiving a verdict — at which point the coordinator already holds
/// this shard's report and is not waiting on it.
fn heartbeat_loop(
    conn: &Mutex<Box<dyn Transport>>,
    stop: &AtomicBool,
    shard: usize,
    interval: Duration,
) {
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let sent = crate::util::failpoints::retry_io("transport.heartbeat", || {
            let mut c = conn.lock().unwrap_or_else(|p| p.into_inner());
            c.send(&Frame::new(
                FrameKind::Heartbeat,
                shard as u8,
                COORD_ID,
                0,
                Vec::new(),
            ))
            .map_err(|e| io::Error::other(e.to_string()))
        });
        if sent.is_err() {
            return;
        }
        // Sleep in short steps so shutdown never waits a full interval.
        let mut slept = Duration::ZERO;
        while slept < interval {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            let step = Duration::from_millis(25).min(interval - slept);
            crate::util::sync::thread::sleep(step);
            slept += step;
        }
    }
}

fn shard_serve_loop(
    graph: &Arc<Graph>,
    shard: usize,
    shards: usize,
    conn: &Mutex<Box<dyn Transport>>,
) -> Result<(), FrameError> {
    loop {
        let frame = {
            let mut c = conn.lock().unwrap_or_else(|p| p.into_inner());
            match c.recv() {
                Ok(f) => f,
                Err(FrameError::Closed) => return Ok(()),
                Err(e) => return Err(e),
            }
        };
        match frame.kind {
            FrameKind::Run => shard_run_unit(graph, shard, shards, conn, &frame.payload)?,
            FrameKind::Shutdown => return Ok(()),
            // Stale frames from an aborted unit (a late decision or data
            // frame already in flight) are dropped; the coordinator
            // resynchronizes at the next `Run`.
            _ => {}
        }
    }
}

/// Decode and execute one unit, replying with `Values` on success or an
/// `Error` frame for failures the coordinator can't already know about.
fn shard_run_unit(
    graph: &Arc<Graph>,
    shard: usize,
    shards: usize,
    conn: &Mutex<Box<dyn Transport>>,
    payload: &[u8],
) -> Result<(), FrameError> {
    let send_error = |detail: String| {
        let mut c = conn.lock().unwrap_or_else(|p| p.into_inner());
        c.send(&Frame::new(
            FrameKind::Error,
            shard as u8,
            COORD_ID,
            0,
            detail.into_bytes(),
        ))
    };
    let spec = match decode_run(payload) {
        Ok(s) => s,
        Err(e) => return send_error(format!("bad run frame: {e}")),
    };
    let n = graph.num_vertices();
    let resume = match &spec.resume {
        Some(w) => match wire_to_snapshot(w, n) {
            Ok(s) => Some(s),
            Err(e) => return send_error(format!("bad resume snapshot: {e}")),
        },
        None => None,
    };
    let part = spec.cfg.partitioner.build(graph, spec.workers);
    let plan = WorkerPlan::new(&part, n);
    let mask = spec.seeds.mask(n);
    let program = FnProgram::new(graph, spec.cfg, spec.er, spec.er_count).with_seed_mask(mask);
    let engine = Engine::new(graph, part, program, spec.opts);
    match engine.run_sharded(&plan, shard, shards, conn, spec.ckpt_active, resume) {
        Ok(out) => {
            let wps = spec.workers / shards;
            let mut walks: Vec<(VertexId, &Vec<VertexId>)> = Vec::new();
            for w in shard * wps..(shard + 1) * wps {
                for &vid in plan.vertices(w) {
                    let walk = &out.values[vid as usize].walk;
                    if !walk.is_empty() {
                        walks.push((vid, walk));
                    }
                }
            }
            let payload = encode_values_payload(&engine.program().stats(), &walks);
            let mut c = conn.lock().unwrap_or_else(|p| p.into_inner());
            c.send(&Frame::new(
                FrameKind::Values,
                shard as u8,
                COORD_ID,
                0,
                payload,
            ))
        }
        // Coordinator-decided stops: it already holds the typed error and
        // the fleet stays usable for the next unit (degradation splits),
        // so an `Error` frame here would poison a healthy fleet.
        Err(
            EngineError::OutOfMemory { .. }
            | EngineError::DidNotTerminate { .. }
            | EngineError::Checkpoint { .. },
        ) => Ok(()),
        // The unit died under this shard: an abort decision, a poisoned
        // frame stream, an unexpected frame. The coordinator usually knows
        // already (it decided the abort, or its own reader hit the same
        // stream fault) — but a shard-local fault such as a corrupted
        // frame *to* this shard is invisible over there until a liveness
        // deadline fires, so report it promptly. A duplicate report is
        // harmless: the supervisor tears the whole generation down and
        // drops stale events by generation tag.
        // Genuinely local failures (worker panic, bad config) equally
        // abort the unit fleet-wide.
        Err(e) => send_error(e.to_string()),
    }
}

/// Entry point of the hidden `shard-worker` CLI subcommand: open the
/// graph, dial the coordinator, serve units until shutdown.
pub fn shard_worker_main(args: &[String]) -> Result<(), String> {
    let mut socket: Option<PathBuf> = None;
    let mut shard: Option<usize> = None;
    let mut shards: Option<usize> = None;
    let mut graph_file: Option<PathBuf> = None;
    let mut mmap = false;
    let mut heartbeat_ms: u64 = 2000;
    let mut chaos: Option<ChaosConfig> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--socket" => {
                socket = Some(PathBuf::from(
                    it.next().ok_or("--socket needs a path")?.as_str(),
                ));
            }
            "--shard" => {
                let v = it.next().ok_or("--shard needs a number")?;
                shard = Some(v.parse().map_err(|_| format!("bad --shard `{v}`"))?);
            }
            "--shards" => {
                let v = it.next().ok_or("--shards needs a number")?;
                shards = Some(v.parse().map_err(|_| format!("bad --shards `{v}`"))?);
            }
            "--graph-file" => {
                graph_file = Some(PathBuf::from(
                    it.next().ok_or("--graph-file needs a path")?.as_str(),
                ));
            }
            "--mmap" => mmap = true,
            "--heartbeat-ms" => {
                let v = it.next().ok_or("--heartbeat-ms needs a number")?;
                heartbeat_ms = v
                    .parse()
                    .map_err(|_| format!("bad --heartbeat-ms `{v}`"))?;
            }
            "--chaos" => {
                let v = it.next().ok_or("--chaos needs a spec")?;
                chaos = Some(parse_chaos_arg(v)?);
            }
            other => return Err(format!("unknown shard-worker argument `{other}`")),
        }
    }
    let socket = socket.ok_or("shard-worker: missing --socket")?;
    let shard = shard.ok_or("shard-worker: missing --shard")?;
    let shards = shards.ok_or("shard-worker: missing --shards")?;
    let graph_file = graph_file.ok_or("shard-worker: missing --graph-file")?;
    let generation: u64 = match std::env::var(SHARD_GENERATION_ENV) {
        Ok(v) => v
            .parse()
            .map_err(|_| format!("bad {SHARD_GENERATION_ENV} `{v}`"))?,
        Err(_) => 0,
    };
    arm_failpoints_from_env(shard, generation)?;
    let opts = if mmap {
        OpenOptions::mapped()
    } else {
        OpenOptions::owned()
    };
    let graph = open_graph(&graph_file, &opts)
        .map_err(|e| format!("open {}: {e}", graph_file.display()))?;
    let stream = UnixStream::connect(&socket)
        .map_err(|e| format!("connect {}: {e}", socket.display()))?;
    let mut conn: Box<dyn Transport> = Box::new(UdsTransport::new(stream));
    if let Some(c) = chaos {
        conn = ChaosTransport::wrap(conn, c, shard as u8, CHAOS_DIR_TO_COORD, generation);
    }
    shard_serve(
        &Arc::new(graph),
        shard,
        shards,
        conn,
        Duration::from_millis(heartbeat_ms),
    )
    .map_err(|e| format!("shard {shard}: {e}"))
}

/// Serialize a [`ChaosConfig`] for the `shard-worker --chaos` flag:
/// `seed,drop,dup,delay_pm,delay_ms,flip,trunc[,flip_data_nth]`.
fn encode_chaos_arg(c: &ChaosConfig) -> String {
    let mut s = format!(
        "{},{},{},{},{},{},{}",
        c.seed, c.drop_pm, c.dup_pm, c.delay_pm, c.delay_ms, c.flip_pm, c.trunc_pm
    );
    if let Some(nth) = c.flip_data_nth {
        s.push(',');
        s.push_str(&nth.to_string());
    }
    s
}

fn parse_chaos_arg(s: &str) -> Result<ChaosConfig, String> {
    let parts: Vec<&str> = s.split(',').collect();
    if parts.len() != 7 && parts.len() != 8 {
        return Err(format!(
            "bad --chaos `{s}` (want seed,drop,dup,delay_pm,delay_ms,flip,trunc[,nth])"
        ));
    }
    let num = |i: usize| -> Result<u64, String> {
        parts[i]
            .parse()
            .map_err(|_| format!("bad --chaos field `{}`", parts[i]))
    };
    let mut cfg = ChaosConfig::new(num(0)?);
    cfg.drop_pm = num(1)? as u32;
    cfg.dup_pm = num(2)? as u32;
    cfg.delay_pm = num(3)? as u32;
    cfg.delay_ms = num(4)?;
    cfg.flip_pm = num(5)? as u32;
    cfg.trunc_pm = num(6)? as u32;
    if parts.len() == 8 {
        cfg.flip_data_nth = Some(num(7)?);
    }
    Ok(cfg)
}

/// `FASTN2V_SHARD_FAILPOINT="<shard>:<site>:<nth>[:<gen>]"` arms one
/// failpoint in one specific shard process, with a panic hook that turns
/// the trip into a hard process death — the kill-recovery tests need a
/// genuinely dead shard (EOF on its socket), not the engine's
/// caught-panic typed error. The optional fourth field scopes the arm to
/// one fleet generation (default `0`, i.e. only the launch fleet, so the
/// respawned shard survives its replay); `*` arms every generation (the
/// budget-exhaustion tests need the shard to keep dying).
#[cfg(feature = "failpoints")]
fn arm_failpoints_from_env(shard: usize, generation: u64) -> Result<(), String> {
    let Ok(spec) = std::env::var("FASTN2V_SHARD_FAILPOINT") else {
        return Ok(());
    };
    let parts: Vec<&str> = spec.split(':').collect();
    if parts.len() != 3 && parts.len() != 4 {
        return Err(format!(
            "bad FASTN2V_SHARD_FAILPOINT `{spec}` (want <shard>:<site>:<nth>[:<gen>|:*])"
        ));
    }
    let target: usize = parts[0]
        .parse()
        .map_err(|_| format!("bad failpoint shard `{}`", parts[0]))?;
    if target != shard {
        return Ok(());
    }
    if parts.len() == 4 {
        if parts[3] != "*" {
            let g: u64 = parts[3]
                .parse()
                .map_err(|_| format!("bad failpoint generation `{}`", parts[3]))?;
            if g != generation {
                return Ok(());
            }
        }
    } else if generation != 0 {
        return Ok(());
    }
    let site = crate::util::failpoints::SITES
        .iter()
        .find(|s| s.name == parts[1])
        .ok_or_else(|| format!("unknown failpoint site `{}`", parts[1]))?;
    let nth: u64 = parts[2]
        .parse()
        .map_err(|_| format!("bad failpoint hit index `{}`", parts[2]))?;
    std::panic::set_hook(Box::new(|info| {
        eprintln!("shard worker failpoint tripped: {info}");
        std::process::abort();
    }));
    crate::util::failpoints::arm_fatal(site.name, nth);
    Ok(())
}

#[cfg(not(feature = "failpoints"))]
fn arm_failpoints_from_env(_shard: usize, _generation: u64) -> Result<(), String> {
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{er_graph, GenConfig};
    use crate::node2vec::PartitionerKind;

    fn small_graph() -> Graph {
        er_graph(&GenConfig::new(200, 6, 11))
    }

    #[test]
    fn shard_shares_sum_exactly_to_resident_bytes() {
        let g = small_graph();
        for shards in [1usize, 2, 3, 4, 7] {
            for wps in [1usize, 2] {
                let part = PartitionerKind::Hash.build(&g, shards * wps);
                let shares = shard_shares(&g, &part, shards, wps);
                assert_eq!(shares.len(), shards);
                assert_eq!(
                    shares.iter().sum::<u64>(),
                    g.resident_bytes(),
                    "shares must sum exactly at {shards} shards x {wps} workers"
                );
            }
        }
    }

    #[test]
    fn shard_shares_follow_arc_ownership() {
        let g = small_graph();
        let part = PartitionerKind::Range.build(&g, 4);
        let shares = shard_shares(&g, &part, 4, 1);
        // Every shard owns vertices of this graph, so every share is
        // positive and none swallows the whole budget.
        for (s, &share) in shares.iter().enumerate() {
            assert!(share > 0, "shard {s} got a zero share");
            assert!(share < g.resident_bytes());
        }
    }

    #[test]
    fn run_spec_roundtrips_through_codec() {
        let cfg = FnConfig::new(0.5, 2.0, 42)
            .with_variant(Variant::Cache)
            .with_popular_threshold(64)
            .with_hot_threshold(Some(100));
        let spec = UnitSpec {
            cfg,
            opts: EngineOpts {
                max_supersteps: 99,
                memory_budget: Some(1 << 30), // must NOT survive the trip
                cache_capacity: Some(4096),
                hot_degree_threshold: Some(100),
                strict_memory: true,
                hot_split_cross_shard: false,
            },
            workers: 8,
            er: 1,
            er_count: 4,
            seeds: SeedSet::Explicit(vec![3, 1, 4, 1, 5]),
            ckpt_active: true,
            resume: Some(SnapshotWire {
                superstep: 7,
                value_count: 2,
                values: vec![1, 2, 3],
                msg_count: 1,
                msgs: vec![9, 9],
            }),
        };
        let decoded = decode_run(&encode_run(&spec)).unwrap();
        assert_eq!(decoded.cfg.p, cfg.p);
        assert_eq!(decoded.cfg.q, cfg.q);
        assert_eq!(decoded.cfg.seed, cfg.seed);
        assert_eq!(decoded.cfg.variant, Variant::Cache);
        assert_eq!(decoded.cfg.hot_threshold, Some(100));
        assert_eq!(decoded.opts.max_supersteps, 99);
        assert_eq!(decoded.opts.memory_budget, None, "budget must not ship");
        assert_eq!(decoded.opts.cache_capacity, Some(4096));
        assert!(decoded.opts.strict_memory);
        assert_eq!(decoded.workers, 8);
        assert_eq!(decoded.er, 1);
        assert_eq!(decoded.er_count, 4);
        assert_eq!(decoded.seeds, SeedSet::Explicit(vec![3, 1, 4, 1, 5]));
        assert!(decoded.ckpt_active);
        let res = decoded.resume.unwrap();
        assert_eq!(res.superstep, 7);
        assert_eq!(res.value_count, 2);
        assert_eq!(res.values, vec![1, 2, 3]);
        assert_eq!(res.msg_count, 1);
        assert_eq!(res.msgs, vec![9, 9]);
    }

    #[test]
    fn run_spec_seed_variants_roundtrip() {
        for seeds in [
            SeedSet::All,
            SeedSet::Slice { start: 5, end: 17 },
            SeedSet::Explicit(vec![]),
        ] {
            let spec = UnitSpec {
                cfg: FnConfig::new(1.0, 1.0, 1),
                opts: EngineOpts::default(),
                workers: 4,
                er: 0,
                er_count: 1,
                seeds: seeds.clone(),
                ckpt_active: false,
                resume: None,
            };
            let decoded = decode_run(&encode_run(&spec)).unwrap();
            assert_eq!(decoded.seeds, seeds);
            assert!(decoded.resume.is_none());
        }
    }

    #[test]
    fn values_payload_roundtrips() {
        let stats = WalkStats {
            exact_steps: 10,
            approx_steps: 2,
            local_reads: 3,
            cache_hits: 4,
            truncated_walks: 1,
            ..Default::default()
        };
        let w0: Vec<VertexId> = vec![5, 6, 2, 9];
        let w1: Vec<VertexId> = vec![7];
        let walks = vec![(5u32, &w0), (7u32, &w1)];
        let payload = encode_values_payload(&stats, &walks);
        let (got_stats, got_walks) = decode_values(&payload).unwrap();
        assert_eq!(got_stats.exact_steps, 10);
        assert_eq!(got_stats.approx_steps, 2);
        assert_eq!(got_stats.truncated_walks, 1);
        assert!(got_stats.per_round.is_empty());
        assert_eq!(got_walks, vec![(5, w0), (7, w1)]);
    }

    #[test]
    fn ckpt_part_payload_roundtrips() {
        let mut payload = Vec::new();
        put_u64(&mut payload, 3);
        put_u64(&mut payload, 4);
        payload.extend_from_slice(&[1, 2, 3, 4]);
        put_u64(&mut payload, 2);
        put_u64(&mut payload, 2);
        payload.extend_from_slice(&[5, 6]);
        let part = decode_ckpt_part(&payload).unwrap();
        assert_eq!(part.value_count, 3);
        assert_eq!(part.values, vec![1, 2, 3, 4]);
        assert_eq!(part.msg_count, 2);
        assert_eq!(part.msgs, vec![5, 6]);
        assert!(decode_ckpt_part(&payload[..payload.len() - 1]).is_err());
    }

    #[test]
    fn snapshot_wire_roundtrips_dense_state() {
        let n = 6usize;
        let mut values: Vec<FnValue> = Vec::new();
        values.resize_with(n, FnValue::default);
        values[2].walk = vec![2, 4, 1];
        values[5].walk = vec![5];
        let mut halted = vec![false; n];
        halted[1] = true;
        let messages = vec![(
            3u32,
            FnMsg::Step {
                start: 3,
                idx: 1,
                vertex: 4,
            },
        )];
        let snap = EngineSnapshot::<FnProgram> {
            superstep: 9,
            values,
            halted,
            messages,
        };
        let wire = snapshot_to_wire(&snap);
        assert_eq!(wire.value_count, n as u64);
        assert_eq!(wire.msg_count, 1);
        let back = wire_to_snapshot(&wire, n).unwrap();
        assert_eq!(back.superstep, 9);
        assert_eq!(back.values[2].walk, vec![2, 4, 1]);
        assert_eq!(back.values[5].walk, vec![5]);
        assert!(back.values[0].walk.is_empty());
        assert!(back.halted[1]);
        assert!(!back.halted[0]);
        assert_eq!(back.messages.len(), 1);
        assert_eq!(back.messages[0].0, 3);
        // Wrong graph size is a decode error, not a truncated resume.
        assert!(wire_to_snapshot(&wire, n + 1).is_err());
    }

    #[test]
    fn chaos_arg_roundtrips() {
        let c = ChaosConfig::light(7).with_flip_data_nth(3);
        assert_eq!(parse_chaos_arg(&encode_chaos_arg(&c)).unwrap(), c);
        let plain = ChaosConfig::light(9);
        assert_eq!(parse_chaos_arg(&encode_chaos_arg(&plain)).unwrap(), plain);
        assert!(parse_chaos_arg("1,2,3").is_err());
        assert!(parse_chaos_arg("a,2,3,4,5,6,7").is_err());
    }

    #[test]
    fn dist_config_supervision_defaults_and_builders() {
        let d = DistConfig::new(2, 2);
        assert_eq!(d.frame_timeout, Duration::from_secs(120));
        assert_eq!(d.accept_timeout, Duration::from_secs(60));
        assert_eq!(d.reap_timeout, Duration::from_secs(5));
        assert_eq!(d.heartbeat_interval, Duration::from_secs(2));
        assert_eq!(d.liveness_timeout, Duration::from_secs(15));
        assert_eq!(d.restart_budget, 3);
        assert!(d.chaos.is_none());
        let d = d
            .with_frame_timeout(Duration::from_secs(2))
            .with_heartbeat_interval(Duration::from_millis(50))
            .with_liveness_timeout(Duration::from_millis(500))
            .with_restart_budget(0)
            .with_backoff(Duration::from_millis(1), Duration::from_millis(10))
            .with_chaos(ChaosConfig::light(1));
        assert_eq!(d.frame_timeout, Duration::from_secs(2));
        assert_eq!(d.heartbeat_interval, Duration::from_millis(50));
        assert_eq!(d.liveness_timeout, Duration::from_millis(500));
        assert_eq!(d.restart_budget, 0);
        assert_eq!(d.backoff_base, Duration::from_millis(1));
        assert_eq!(d.backoff_cap, Duration::from_millis(10));
        assert_eq!(d.chaos, Some(ChaosConfig::light(1)));
    }

    #[test]
    fn transport_kind_parses_its_own_names() {
        for k in [TransportKind::InProc, TransportKind::Uds] {
            assert_eq!(TransportKind::parse(k.name()), Some(k));
        }
        assert_eq!(TransportKind::parse("tcp"), None);
    }

    #[test]
    fn launch_rejects_bad_shapes() {
        let g = Arc::new(small_graph());
        let part = PartitionerKind::Hash.build(&g, 4);
        let err = Coordinator::launch(&g, &part, &DistConfig::new(0, 1)).unwrap_err();
        assert!(matches!(err, EngineError::Config { .. }));
        let err = Coordinator::launch(&g, &part, &DistConfig::new(3, 1)).unwrap_err();
        assert!(
            matches!(err, EngineError::Config { .. }),
            "4 workers cannot back 3 shards x 1 worker"
        );
    }
}
