//! Parallel SGNS: the lock-free multi-threaded training subsystem.
//!
//! PRs 1–4 made walk generation scale across cores; this module does the
//! same for the SGNS optimization stage so embedding keeps pace with the
//! walk engine (the async multi-threaded SGD node2vec and DistGER train
//! with — see EXPERIMENTS.md §Train). Three layers:
//!
//! - [`EmbeddingMatrix`] — both embedding tables (`w_in` rows `[0, n)`,
//!   `w_out` rows `[n, 2n)`) in **one contiguous allocation** behind
//!   `UnsafeCell`, so worker threads share it without locks and the row
//!   kernels see exact `dim`-length slices (bounds checks elided,
//!   update loops auto-vectorizable).
//! - a persistent fork-join worker pool (spawned once per trainer, parked
//!   on a condvar between steps) plus a producer/consumer **batch pipeline**:
//!   dedicated sampler threads pre-draw `(centers, positives, negatives)`
//!   batches from the [`Corpus`] so the SGD inner loop never stalls on
//!   alias-table sampling.
//! - [`ParallelSgns`] — an [`SgnsBackend`] running SGD across
//!   `TrainConfig::threads` workers in one of two disciplines
//!   ([`TrainMode`]):
//!
//! **`hogwild`** (default): workers update the shared matrix with no
//! synchronization at all, the Hogwild recipe (Recht et al., 2011) that
//! word2vec and node2vec train with. Sparse gradients make write
//! collisions rare, so the loss trajectory is statistically equivalent to
//! serial SGD, but concurrent unsynchronized float updates mean runs are
//! **not bit-reproducible** for `threads > 1`. With `threads == 1` the
//! whole path degenerates to exactly the serial oracle: bit-identical
//! loss curves and embeddings to [`RustSgns`](super::RustSgns) (pinned in
//! `tests/parallel_train.rs`).
//!
//! **`sharded`**: bit-deterministic for *any* thread count — and
//! identical *across* thread counts. Each step is synchronous and
//! two-phase: phase 1 computes every pair's gradient coefficients (and
//! snapshots the center rows) against the frozen start-of-step matrix;
//! phase 2 applies updates where each thread writes only the rows it owns
//! (`owner(v) = v % threads`), scanning pairs in batch order. A row's
//! update sequence is therefore a pure function of the batch, never of
//! the schedule. The price is mini-batch-style (frozen-gradient)
//! semantics within a step instead of the serial loop's
//! pair-by-pair updates, so `sharded` at `threads == 1` is deterministic
//! but intentionally *not* the oracle bit pattern.
//!
//! Determinism of batch content (independent of the worker schedule):
//! - hogwild worker `t` draws from the persistent stream
//!   `stream(seed, 0xBA7C, worker_stream_index(t), 0)`, where index 0 is
//!   the staged oracle stream (bit-parity for one thread) and index 1 is
//!   reserved for [`TrainerSink`](super::TrainerSink)'s stream, so
//!   workers `t >= 1` use `t + 1`;
//! - sharded step `s` draws from `stream(seed, 0x50A8, 0, s)` — keyed by
//!   the global step only, which is what makes the whole trajectory
//!   thread-count-invariant.
//!
//! Both schedules are mirrored by the toolchain-free executable spec
//! `python/tests/test_sgns_parallel_spec.py`.

use std::cell::UnsafeCell;
use std::ops::Range;

use super::{sigmoid, softplus, Corpus, LossPoint, SgnsBackend, TrainConfig};
use crate::util::error::Result;
use crate::util::rng::stream;
use crate::util::sync::atomic::{AtomicU32, Ordering};
use crate::util::sync::pipeline::StepPipeline;
use crate::util::sync::pool::WorkerPool;
use crate::util::sync::queue::BoundedQueue;
use crate::util::sync::{thread, Mutex};

/// Parallel update discipline — see the module docs for the trade-off.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMode {
    /// Lock-free asynchronous updates: max throughput, loss-equivalent,
    /// not bit-reproducible above one thread.
    Hogwild,
    /// Two-phase owned-row updates: bit-deterministic for any thread
    /// count and identical across thread counts.
    Sharded,
}

impl TrainMode {
    pub const ALL: [TrainMode; 2] = [TrainMode::Hogwild, TrainMode::Sharded];

    pub fn name(self) -> &'static str {
        match self {
            TrainMode::Hogwild => "hogwild",
            TrainMode::Sharded => "sharded",
        }
    }

    pub fn parse(s: &str) -> Option<TrainMode> {
        match s {
            "hogwild" => Some(TrainMode::Hogwild),
            "sharded" => Some(TrainMode::Sharded),
            _ => None,
        }
    }
}

/// Batch-stream tag for sharded-mode per-step RNG streams (the hogwild /
/// staged tag is [`super::BATCH_STREAM_TAG`]). Mirrored in
/// `python/tests/test_sgns_parallel_spec.py`.
pub(crate) const SHARDED_BATCH_TAG: u64 = 0x50A8;

/// Bounded lookahead of the sharded batch pipeline: producers may run at
/// most this many steps ahead of the consumer.
pub(crate) const PIPELINE_DEPTH: u32 = 8;

/// Per-worker batch queue depth of the hogwild pipeline.
pub(crate) const HOGWILD_QUEUE_DEPTH: usize = 4;

/// Dedicated sampler (producer) threads for a given SGD worker count.
/// Sampling is a fraction of step cost, so one producer feeds ~4 workers.
pub(crate) fn producer_count(threads: usize) -> usize {
    (threads / 4).max(1)
}

/// RNG stream index of hogwild worker `t`: index 0 *is* the staged oracle
/// stream (single-thread bit-parity); index 1 belongs to `TrainerSink`,
/// so workers `t >= 1` shift past it.
pub(crate) fn worker_stream_index(t: usize) -> u64 {
    if t == 0 {
        0
    } else {
        t as u64 + 1
    }
}

/// Which thread owns vertex `v`'s rows in sharded mode.
#[inline]
pub(crate) fn shard_owner(v: usize, threads: usize) -> usize {
    v % threads
}

// ---------------------------------------------------------------------------
// Kernels: exact-`dim` slices over the flat tables. The slices are produced
// by `from_raw_parts(_mut)` with a compile-time-opaque but loop-constant
// length, so the zipped loops compile without bounds checks and the update
// (axpy) loops auto-vectorize; the dot reduction stays a serial chain, which
// is what keeps it bit-identical to the historical scalar loop.
// ---------------------------------------------------------------------------

#[inline(always)]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// `y[j] += alpha * x[j]`.
#[inline(always)]
pub(crate) fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yj, xj) in y.iter_mut().zip(x) {
        *yj += alpha * xj;
    }
}

/// `y[j] = alpha * x[j]` (fresh write — avoids a zeroing pass).
#[inline(always)]
pub(crate) fn scale_into(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yj, xj) in y.iter_mut().zip(x) {
        *yj = alpha * xj;
    }
}

/// One batch's `(center, positive, negatives)` index triples, passed to
/// the kernels as a unit (the param-struct fix for what used to be a
/// `clippy::too_many_arguments` allow).
#[derive(Clone, Copy)]
pub(crate) struct PairBatch<'a> {
    pub centers: &'a [i32],
    pub positives: &'a [i32],
    pub negatives: &'a [i32],
}

impl<'a> PairBatch<'a> {
    pub(crate) fn new(
        centers: &'a [i32],
        positives: &'a [i32],
        negatives: &'a [i32],
    ) -> PairBatch<'a> {
        PairBatch {
            centers,
            positives,
            negatives,
        }
    }

    /// Pairs in the batch.
    #[inline]
    pub(crate) fn len(&self) -> usize {
        self.centers.len()
    }

    /// Negatives per pair.
    #[inline]
    pub(crate) fn k(&self) -> usize {
        if self.centers.is_empty() {
            0
        } else {
            self.negatives.len() / self.centers.len()
        }
    }
}

/// One serial SGNS pass over `range` of the batch against flat tables.
/// Returns the raw (not batch-normalized) f64 loss total.
///
/// This is *the* update kernel: `RustSgns::step` runs it over its own
/// `Vec`s and every `ParallelSgns` worker runs it over the shared
/// [`EmbeddingMatrix`], so single-thread bit-parity with the oracle is
/// structural, not coincidental. Op order matches the historical scalar
/// loop exactly (`dc` accumulates against pre-update `w_out`; `a - b*c`
/// is computed as `a + (-b)*c`, which is IEEE-bitwise identical).
///
/// # Safety
/// `w_in`/`w_out` must point to `>= max_id * dim` valid f32s each, and all
/// ids in the batch slices must be in range. Exclusive access is the
/// caller's contract — hogwild callers intentionally run this concurrently
/// over overlapping rows and accept the benign data races.
pub(crate) unsafe fn sgd_step_range(
    w_in: *mut f32,
    w_out: *mut f32,
    dim: usize,
    pairs: PairBatch<'_>,
    lr: f32,
    range: Range<usize>,
    dc: &mut [f32],
) -> f64 {
    debug_assert_eq!(dc.len(), dim);
    let k = pairs.k();
    let mut total = 0f64;
    for i in range {
        let c = pairs.centers[i] as usize;
        let o = pairs.positives[i] as usize;
        let wc = std::slice::from_raw_parts_mut(w_in.add(c * dim), dim);
        // Positive pair.
        {
            let wo = std::slice::from_raw_parts_mut(w_out.add(o * dim), dim);
            let pos = dot(wc, wo);
            let gp = sigmoid(pos) - 1.0;
            total += softplus(-pos) as f64;
            scale_into(gp, wo, dc);
            axpy(-lr * gp, wc, wo);
        }
        // Negatives.
        for s in 0..k {
            let nv = pairs.negatives[i * k + s] as usize;
            let wn = std::slice::from_raw_parts_mut(w_out.add(nv * dim), dim);
            let neg = dot(wc, wn);
            let gn = sigmoid(neg);
            total += softplus(neg) as f64;
            axpy(gn, wn, dc);
            axpy(-lr * gn, wc, wn);
        }
        axpy(-lr, dc, wc);
    }
    total
}

// ---------------------------------------------------------------------------
// EmbeddingMatrix
// ---------------------------------------------------------------------------

/// Both SGNS tables in a single contiguous `UnsafeCell` allocation:
/// `w_in` occupies rows `[0, n)`, `w_out` rows `[n, 2n)` of a
/// `2 * n * dim` float block. Shared by value-less reference across the
/// worker pool; the mode discipline (hogwild races vs sharded ownership)
/// governs write access.
pub struct EmbeddingMatrix {
    num_vertices: usize,
    dim: usize,
    data: Box<[UnsafeCell<f32>]>,
}

// SAFETY: all mutation goes through raw pointers derived from the
// UnsafeCells under the mode disciplines documented on the module.
unsafe impl Sync for EmbeddingMatrix {}

impl EmbeddingMatrix {
    /// Same init distribution *and bit pattern* as
    /// [`RustSgns::new`](super::RustSgns::new) (both call the shared
    /// `init_tables`).
    pub fn new(num_vertices: usize, dim: usize, seed: u64) -> EmbeddingMatrix {
        let (w_in, w_out) = super::init_tables(num_vertices, dim, seed);
        let data: Vec<UnsafeCell<f32>> =
            w_in.into_iter().chain(w_out).map(UnsafeCell::new).collect();
        EmbeddingMatrix {
            num_vertices,
            dim,
            data: data.into_boxed_slice(),
        }
    }

    pub fn num_vertices(&self) -> usize {
        self.num_vertices
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    fn base(&self) -> *mut f32 {
        UnsafeCell::raw_get(self.data.as_ptr())
    }

    #[inline]
    pub(crate) fn w_in_ptr(&self) -> *mut f32 {
        self.base()
    }

    #[inline]
    pub(crate) fn w_out_ptr(&self) -> *mut f32 {
        // SAFETY: the allocation holds 2 * n * dim floats, so the offset
        // n * dim stays inside it.
        unsafe { self.base().add(self.num_vertices * self.dim) }
    }

    /// Flat row-major view of the input embeddings (the hot read path —
    /// no per-row cloning). Only call between training steps: the view
    /// aliases the cells workers write through.
    pub fn w_in(&self) -> &[f32] {
        // SAFETY: rows [0, n) of the allocation are n * dim initialized
        // f32s; no worker writes between steps (documented contract).
        unsafe { std::slice::from_raw_parts(self.w_in_ptr(), self.num_vertices * self.dim) }
    }

    /// Flat row-major view of the output (context) embeddings.
    pub fn w_out(&self) -> &[f32] {
        // SAFETY: rows [n, 2n) of the allocation are n * dim initialized
        // f32s; no worker writes between steps (documented contract).
        unsafe { std::slice::from_raw_parts(self.w_out_ptr(), self.num_vertices * self.dim) }
    }

    /// Row-per-vertex copy of `w_in` (the legacy
    /// [`SgnsBackend::final_embeddings`] shape), materialized through
    /// the one shared flat→rows boundary.
    pub fn embeddings(&self) -> Vec<Vec<f32>> {
        crate::embed::rows_from_flat(self.w_in(), self.dim)
    }

    /// Overwrite both tables from flat snapshots (checkpoint restore).
    /// Takes `&mut self`, so no worker can be mid-step through the cells.
    pub fn load(&mut self, w_in: &[f32], w_out: &[f32]) -> std::result::Result<(), String> {
        let len = self.num_vertices * self.dim;
        if w_in.len() != len || w_out.len() != len {
            return Err(format!(
                "embedding snapshot shape mismatch: got {}+{} floats, table is 2x{len}",
                w_in.len(),
                w_out.len()
            ));
        }
        // SAFETY: both destinations are `len` in-bounds f32s (checked
        // above), the sources don't alias them (distinct allocations),
        // and `&mut self` rules out concurrent access through the cells.
        unsafe {
            std::ptr::copy_nonoverlapping(w_in.as_ptr(), self.w_in_ptr(), len);
            std::ptr::copy_nonoverlapping(w_out.as_ptr(), self.w_out_ptr(), len);
        }
        Ok(())
    }

    /// Read a row of `w_in` for sharded phase 1 (frozen-matrix reads).
    ///
    /// # Safety
    /// No thread may be writing the row (true in phase 1 by construction).
    #[inline]
    unsafe fn row_in_ref(&self, v: usize) -> &[f32] {
        std::slice::from_raw_parts(self.w_in_ptr().add(v * self.dim), self.dim)
    }

    /// Read a row of `w_out` for sharded phase 1.
    ///
    /// # Safety
    /// No thread may be writing the row.
    #[inline]
    unsafe fn row_out_ref(&self, v: usize) -> &[f32] {
        std::slice::from_raw_parts(self.w_out_ptr().add(v * self.dim), self.dim)
    }

    /// Mutable row of `w_in` for sharded phase 2.
    ///
    /// # Safety
    /// Caller must hold exclusive write ownership of the row (sharded
    /// phase 2 guarantees it via `owner(v) = v % threads`).
    //
    // The `mut_from_ref` allow is sound, not a lint dodge: the `&mut`
    // derives from `UnsafeCell` contents (the one legal interior-
    // mutability route), the method is `unsafe`, and its contract —
    // exclusive row ownership — is exactly the aliasing condition the
    // lint cannot see. This is `UnsafeCell::get`-style API shape.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    unsafe fn row_in_mut(&self, v: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.w_in_ptr().add(v * self.dim), self.dim)
    }

    /// Mutable row of `w_out` for sharded phase 2.
    ///
    /// # Safety
    /// As [`EmbeddingMatrix::row_in_mut`].
    //
    // Allow justified as on `row_in_mut`.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    unsafe fn row_out_mut(&self, v: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.w_out_ptr().add(v * self.dim), self.dim)
    }
}

// ---------------------------------------------------------------------------
// A &mut [T] that can cross into pool workers writing disjoint regions.
// ---------------------------------------------------------------------------

struct RawSlice<T> {
    ptr: *mut T,
    len: usize,
}

impl<T> Clone for RawSlice<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for RawSlice<T> {}

// SAFETY: workers write *disjoint* index ranges (the caller's contract on
// `slice`), and the borrow the RawSlice was built from outlives the pool
// dispatch (the submitting thread blocks in `WorkerPool::run`).
unsafe impl<T: Send> Send for RawSlice<T> {}
// SAFETY: as above — disjoint ranges make shared references across
// threads safe.
unsafe impl<T: Send> Sync for RawSlice<T> {}

impl<T> RawSlice<T> {
    fn new(s: &mut [T]) -> RawSlice<T> {
        RawSlice {
            ptr: s.as_mut_ptr(),
            len: s.len(),
        }
    }

    /// # Safety
    /// Ranges handed to concurrently running workers must not overlap.
    #[inline]
    unsafe fn slice(&self, range: Range<usize>) -> &mut [T] {
        debug_assert!(range.start <= range.end && range.end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.end - range.start)
    }
}

// ---------------------------------------------------------------------------
// Batch pipeline plumbing (the pool/queue/pipeline primitives themselves
// live in `crate::util::sync`, where they are shared and model-checked)
// ---------------------------------------------------------------------------

/// One pre-sampled SGNS batch.
struct Batch {
    centers: Vec<i32>,
    positives: Vec<i32>,
    negatives: Vec<i32>,
}

impl Batch {
    fn new(b: usize, k: usize) -> Batch {
        Batch {
            centers: vec![0i32; b],
            positives: vec![0i32; b],
            negatives: vec![0i32; b * k],
        }
    }
}

// ---------------------------------------------------------------------------
// ParallelSgns
// ---------------------------------------------------------------------------

/// Reusable sharded-mode scratch: per-pair gradient coefficients and
/// frozen center rows, sized `O(batch * (dim + negatives))`.
#[derive(Default)]
struct ShardScratch {
    /// Positive-pair gradient coefficient per pair (`b`).
    gp: Vec<f32>,
    /// Negative gradient coefficients (`b * k`).
    gn: Vec<f32>,
    /// Frozen start-of-step center rows (`b * dim`).
    cin: Vec<f32>,
    /// Center gradients against the frozen matrix (`b * dim`).
    dc: Vec<f32>,
    /// Per-pair loss terms, summed sequentially by the master so the
    /// reported loss is identical for every thread count (`b`).
    loss: Vec<f64>,
}

impl ShardScratch {
    fn ensure(&mut self, b: usize, k: usize, d: usize) {
        self.gp.resize(b, 0.0);
        self.gn.resize(b * k, 0.0);
        self.cin.resize(b * d, 0.0);
        self.dc.resize(b * d, 0.0);
        self.loss.resize(b, 0.0);
    }
}

/// Multi-threaded SGNS trainer over a shared flat [`EmbeddingMatrix`].
///
/// Implements [`SgnsBackend`], so [`TrainerSink`](super::TrainerSink)
/// pipelines walk rounds into it unchanged; [`ParallelSgns::train`] is the
/// staged entry point with the producer/consumer batch pipeline. See the
/// module docs for the `hogwild` / `sharded` trade-off.
pub struct ParallelSgns {
    matrix: EmbeddingMatrix,
    mode: TrainMode,
    threads: usize,
    pool: Option<WorkerPool>,
    shard: ShardScratch,
    /// Serial-path center-gradient scratch (threads == 1).
    dc: Vec<f32>,
}

impl ParallelSgns {
    pub fn new(
        num_vertices: usize,
        dim: usize,
        seed: u64,
        threads: usize,
        mode: TrainMode,
    ) -> ParallelSgns {
        let threads = threads.max(1);
        ParallelSgns {
            matrix: EmbeddingMatrix::new(num_vertices, dim, seed),
            mode,
            threads,
            pool: (threads > 1).then(|| WorkerPool::new(threads)),
            shard: ShardScratch::default(),
            dc: vec![0f32; dim],
        }
    }

    /// Construct from a [`TrainConfig`]'s `seed`/`threads`/`mode`.
    pub fn from_config(num_vertices: usize, dim: usize, cfg: &TrainConfig) -> ParallelSgns {
        ParallelSgns::new(num_vertices, dim, cfg.seed, cfg.threads, cfg.mode)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn mode(&self) -> TrainMode {
        self.mode
    }

    pub fn dim(&self) -> usize {
        self.matrix.dim()
    }

    pub fn matrix(&self) -> &EmbeddingMatrix {
        &self.matrix
    }

    /// Flat row-major `w_in` view — the zero-copy hot read path.
    pub fn embeddings_flat(&self) -> &[f32] {
        self.matrix.w_in()
    }

    /// Legacy row-per-vertex copy.
    pub fn embeddings(&self) -> Vec<Vec<f32>> {
        self.matrix.embeddings()
    }

    /// One SGD step over a caller-supplied batch (the [`SgnsBackend`]
    /// surface). Mean batch loss back.
    pub fn step(&mut self, centers: &[i32], positives: &[i32], negatives: &[i32], lr: f32) -> f32 {
        match self.mode {
            TrainMode::Hogwild => self.step_hogwild(centers, positives, negatives, lr),
            TrainMode::Sharded => self.step_sharded(centers, positives, negatives, lr),
        }
    }

    fn step_hogwild(
        &mut self,
        centers: &[i32],
        positives: &[i32],
        negatives: &[i32],
        lr: f32,
    ) -> f32 {
        let b = centers.len();
        if b == 0 {
            return 0.0;
        }
        let t_count = self.threads;
        if t_count <= 1 {
            // Exactly the serial oracle step (bit-parity with RustSgns).
            let (w_in, w_out, d) = (
                self.matrix.w_in_ptr(),
                self.matrix.w_out_ptr(),
                self.matrix.dim(),
            );
            // SAFETY: the tables hold n * dim f32s each, ids come from
            // the corpus (all < n), and `&mut self` gives this thread
            // exclusive access.
            let total = unsafe {
                sgd_step_range(
                    w_in,
                    w_out,
                    d,
                    PairBatch::new(centers, positives, negatives),
                    lr,
                    0..b,
                    &mut self.dc,
                )
            };
            return (total / b as f64) as f32;
        }
        let mut partials = vec![0f64; t_count];
        let partials = RawSlice::new(&mut partials);
        // Raw table pointers are derived inside each worker (the closure
        // must be Sync, and the matrix reference is).
        let matrix = &self.matrix;
        let pool = self.pool.as_ref().expect("pool exists for threads > 1");
        pool.run(&|t: usize| {
            let lo = t * b / t_count;
            let hi = (t + 1) * b / t_count;
            let d = matrix.dim();
            let mut dc = vec![0f32; d];
            // SAFETY: contiguous pair chunks are disjoint; row updates race
            // across threads by design (hogwild).
            let total = unsafe {
                sgd_step_range(
                    matrix.w_in_ptr(),
                    matrix.w_out_ptr(),
                    d,
                    PairBatch::new(centers, positives, negatives),
                    lr,
                    lo..hi,
                    &mut dc,
                )
            };
            // SAFETY: worker t writes only index t — disjoint ranges.
            unsafe { partials.slice(t..t + 1)[0] = total };
        });
        // SAFETY: pool.run returned, workers are parked again.
        let total: f64 = unsafe { partials.slice(0..t_count) }.iter().sum();
        (total / b as f64) as f32
    }

    fn step_sharded(
        &mut self,
        centers: &[i32],
        positives: &[i32],
        negatives: &[i32],
        lr: f32,
    ) -> f32 {
        let b = centers.len();
        if b == 0 {
            return 0.0;
        }
        let k = negatives.len() / b;
        let d = self.matrix.dim();
        self.shard.ensure(b, k, d);
        let matrix = &self.matrix;
        let t_count = self.threads;
        let pairs = PairBatch::new(centers, positives, negatives);
        {
            let scratch = ShardSlices {
                gp: RawSlice::new(&mut self.shard.gp),
                gn: RawSlice::new(&mut self.shard.gn),
                cin: RawSlice::new(&mut self.shard.cin),
                dcs: RawSlice::new(&mut self.shard.dc),
                loss: RawSlice::new(&mut self.shard.loss),
            };
            let phase1 = |t: usize| {
                let lo = t * b / t_count;
                let hi = (t + 1) * b / t_count;
                // SAFETY: per-pair scratch regions are disjoint across the
                // contiguous chunks; the matrix is only *read* in phase 1.
                unsafe { sharded_grad_range(matrix, pairs, k, lo..hi, scratch) };
            };
            match &self.pool {
                Some(pool) => pool.run(&phase1),
                None => phase1(0),
            }
        }
        // Barrier passed: scratch is fully written; apply owned rows.
        let reads = ShardReads {
            gp: &self.shard.gp[..b],
            gn: &self.shard.gn[..b * k],
            cin: &self.shard.cin[..b * d],
            dcs: &self.shard.dc[..b * d],
        };
        let phase2 = |t: usize| {
            // SAFETY: each row is written by exactly one thread
            // (`owner(v) = v % t_count`), in global pair order.
            unsafe { sharded_apply_owned(matrix, pairs, k, lr, t_count, t, reads) };
        };
        match &self.pool {
            Some(pool) => pool.run(&phase2),
            None => phase2(0),
        }
        // Sequential per-pair sum: the loss is bit-identical for every
        // thread count, not just every run.
        let total: f64 = self.shard.loss[..b].iter().sum();
        (total / b as f64) as f32
    }

    /// Staged training over a corpus, mirroring
    /// [`RustSgns::train`](super::RustSgns::train)'s schedule (linear lr
    /// decay over `cfg.steps`, same logging cadence).
    ///
    /// - `hogwild`, one thread: byte-for-byte the oracle trajectory (same
    ///   batch stream, same kernel).
    /// - `hogwild`, N threads: the step budget splits across workers,
    ///   each draining its own pre-sampled batch queue; worker 0 records
    ///   the loss curve at its share of the global schedule.
    /// - `sharded`: synchronous global steps fed by producer threads
    ///   through an in-order pipeline; bit-identical for any thread
    ///   count.
    pub fn train(
        &mut self,
        corpus: &Corpus,
        cfg: &TrainConfig,
        batch: usize,
        k: usize,
    ) -> Vec<LossPoint> {
        match self.mode {
            TrainMode::Hogwild if self.threads <= 1 => self.train_serial(corpus, cfg, batch, k),
            TrainMode::Hogwild => self.train_hogwild(corpus, cfg, batch, k),
            TrainMode::Sharded => self.train_sharded(corpus, cfg, batch, k),
        }
    }

    /// The oracle loop verbatim (shared stream, serial kernel).
    fn train_serial(
        &mut self,
        corpus: &Corpus,
        cfg: &TrainConfig,
        batch: usize,
        k: usize,
    ) -> Vec<LossPoint> {
        let mut bt = Batch::new(batch, k);
        let mut curve = Vec::new();
        let mut rng = stream(cfg.seed, super::BATCH_STREAM_TAG, 0, 0);
        for step in 0..cfg.steps {
            let t = step as f32 / cfg.steps.max(1) as f32;
            let lr = cfg.lr_start + (cfg.lr_end - cfg.lr_start) * t;
            corpus.fill_batch(
                &mut rng,
                cfg.window,
                &mut bt.centers,
                &mut bt.positives,
                &mut bt.negatives,
            );
            let loss = self.step(&bt.centers, &bt.positives, &bt.negatives, lr);
            if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
                curve.push(LossPoint { step, loss });
            }
        }
        curve
    }

    fn train_hogwild(
        &mut self,
        corpus: &Corpus,
        cfg: &TrainConfig,
        batch: usize,
        k: usize,
    ) -> Vec<LossPoint> {
        let t_count = self.threads;
        let steps = cfg.steps;
        // Worker t's share; the union of global indices j * T + t over all
        // workers is exactly 0..steps, so the lr schedule visits the
        // oracle's values once each (spec-mirrored).
        let share: Vec<u32> = (0..t_count as u32)
            .map(|t| steps / t_count as u32 + u32::from(t < steps % t_count as u32))
            .collect();
        let queues: Vec<BoundedQueue<Batch>> = (0..t_count)
            .map(|_| BoundedQueue::new(HOGWILD_QUEUE_DEPTH))
            .collect();
        let producers = producer_count(t_count);
        let curve = Mutex::new(Vec::new());
        let matrix = &self.matrix;
        let pool = self.pool.as_ref().expect("pool exists for threads > 1");
        let (queues, share) = (&queues, &share);
        thread::scope(|sc| {
            for p in 0..producers {
                sc.spawn(move || {
                    // Producer p owns workers t ≡ p (mod producers) and
                    // drains each owned worker's persistent stream in
                    // order, round-robin so no queue starves. A sampling
                    // panic closes every queue first so no worker blocks
                    // on a dead producer.
                    let produce = || {
                        let mut jobs: Vec<(usize, crate::util::rng::Xoshiro256pp, u32)> =
                            (0..t_count)
                                .filter(|t| t % producers == p)
                                .map(|t| {
                                    let idx = worker_stream_index(t);
                                    (t, stream(cfg.seed, super::BATCH_STREAM_TAG, idx, 0), share[t])
                                })
                                .collect();
                        while !jobs.is_empty() {
                            jobs.retain_mut(|(t, rng, left)| {
                                if *left == 0 {
                                    return false;
                                }
                                let mut bt = Batch::new(batch, k);
                                corpus.fill_batch(
                                    rng,
                                    cfg.window,
                                    &mut bt.centers,
                                    &mut bt.positives,
                                    &mut bt.negatives,
                                );
                                queues[*t].push(bt);
                                *left -= 1;
                                *left > 0
                            });
                        }
                    };
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(produce));
                    if let Err(panic) = outcome {
                        for q in queues.iter() {
                            q.close();
                        }
                        std::panic::resume_unwind(panic);
                    }
                });
            }
            let body = |t: usize| {
                let (w_in, w_out, d) = (matrix.w_in_ptr(), matrix.w_out_ptr(), matrix.dim());
                let my_steps = share[t];
                let mut dc = vec![0f32; d];
                for j in 0..my_steps {
                    // Global lr index of this worker's j-th step.
                    let g = u64::from(j) * t_count as u64 + t as u64;
                    let frac = g as f32 / steps.max(1) as f32;
                    let lr = cfg.lr_start + (cfg.lr_end - cfg.lr_start) * frac;
                    let bt = queues[t].pop();
                    // SAFETY: hogwild — racy row updates by design.
                    let total = unsafe {
                        sgd_step_range(
                            w_in,
                            w_out,
                            d,
                            PairBatch::new(&bt.centers, &bt.positives, &bt.negatives),
                            lr,
                            0..batch,
                            &mut dc,
                        )
                    };
                    if t == 0
                        && cfg.log_every > 0
                        && (g % u64::from(cfg.log_every) == 0 || j + 1 == my_steps)
                    {
                        let loss = (total / batch as f64) as f32;
                        curve.lock().unwrap().push(LossPoint {
                            step: g as u32,
                            loss,
                        });
                    }
                }
            };
            // A worker panic re-raises out of `run`; close the queues
            // before unwinding so blocked producers exit instead of
            // hanging the scope join.
            let outcome =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.run(&body)));
            for q in queues.iter() {
                q.close();
            }
            if let Err(panic) = outcome {
                std::panic::resume_unwind(panic);
            }
        });
        curve.into_inner().unwrap()
    }

    fn train_sharded(
        &mut self,
        corpus: &Corpus,
        cfg: &TrainConfig,
        batch: usize,
        k: usize,
    ) -> Vec<LossPoint> {
        let steps = cfg.steps;
        let pipeline = StepPipeline::new(PIPELINE_DEPTH);
        let producers = producer_count(self.threads);
        let next = AtomicU32::new(0);
        let mut curve = Vec::new();
        let (pipeline_ref, next_ref) = (&pipeline, &next);
        thread::scope(|sc| {
            for _ in 0..producers {
                sc.spawn(move || {
                    let produce = || loop {
                        let s = next_ref.fetch_add(1, Ordering::Relaxed);
                        if s >= steps || !pipeline_ref.await_window(s) {
                            break;
                        }
                        // Keyed by the global step only: batch content is
                        // invariant to thread and producer counts.
                        let mut rng = stream(cfg.seed, SHARDED_BATCH_TAG, 0, u64::from(s));
                        let mut bt = Batch::new(batch, k);
                        corpus.fill_batch(
                            &mut rng,
                            cfg.window,
                            &mut bt.centers,
                            &mut bt.positives,
                            &mut bt.negatives,
                        );
                        pipeline_ref.insert(s, bt);
                    };
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(produce));
                    if let Err(panic) = outcome {
                        // Wake the consumer (its take(s) panics) instead
                        // of leaving it blocked on a dead producer.
                        pipeline_ref.close();
                        std::panic::resume_unwind(panic);
                    }
                });
            }
            let consume = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                for s in 0..steps {
                    let bt = pipeline_ref.take(s);
                    let t = s as f32 / steps.max(1) as f32;
                    let lr = cfg.lr_start + (cfg.lr_end - cfg.lr_start) * t;
                    let loss = self.step_sharded(&bt.centers, &bt.positives, &bt.negatives, lr);
                    if cfg.log_every > 0 && (s % cfg.log_every == 0 || s + 1 == steps) {
                        curve.push(LossPoint { step: s, loss });
                    }
                }
            }));
            // Normal end or consumer panic: release producers parked in
            // await_window before the scope joins them.
            pipeline_ref.close();
            if let Err(panic) = consume {
                std::panic::resume_unwind(panic);
            }
        });
        curve
    }
}

impl SgnsBackend for ParallelSgns {
    fn sgd_step(
        &mut self,
        centers: &[i32],
        positives: &[i32],
        negatives: &[i32],
        lr: f32,
    ) -> Result<f32> {
        Ok(self.step(centers, positives, negatives, lr))
    }

    fn final_embeddings(&self) -> Result<Vec<Vec<f32>>> {
        Ok(self.embeddings())
    }

    fn embeddings_flat(&self) -> Option<(&[f32], usize)> {
        Some((self.matrix.w_in(), self.matrix.dim()))
    }

    fn export_state(&self) -> Option<(Vec<f32>, Vec<f32>)> {
        Some((self.matrix.w_in().to_vec(), self.matrix.w_out().to_vec()))
    }

    fn import_state(&mut self, w_in: &[f32], w_out: &[f32]) -> std::result::Result<(), String> {
        self.matrix.load(w_in, w_out)
    }
}

/// The sharded phase-1 scratch regions as pool-crossing raw slices,
/// passed to [`sharded_grad_range`] as a unit.
#[derive(Clone, Copy)]
struct ShardSlices {
    gp: RawSlice<f32>,
    gn: RawSlice<f32>,
    cin: RawSlice<f32>,
    dcs: RawSlice<f32>,
    loss: RawSlice<f64>,
}

/// The same scratch, frozen after the phase barrier, read by
/// [`sharded_apply_owned`].
#[derive(Clone, Copy)]
struct ShardReads<'a> {
    gp: &'a [f32],
    gn: &'a [f32],
    cin: &'a [f32],
    dcs: &'a [f32],
}

/// Sharded phase 1: for each pair in `range`, compute the gradient
/// coefficients, per-pair loss, the frozen center row snapshot, and the
/// center gradient — all against the start-of-step matrix.
///
/// # Safety
/// `range`s of concurrent callers must be disjoint; no thread may write
/// the matrix while any phase-1 call runs.
unsafe fn sharded_grad_range(
    m: &EmbeddingMatrix,
    pairs: PairBatch<'_>,
    k: usize,
    range: Range<usize>,
    scratch: ShardSlices,
) {
    let d = m.dim();
    for i in range {
        let c = pairs.centers[i] as usize;
        let o = pairs.positives[i] as usize;
        let wc = m.row_in_ref(c);
        let ci = scratch.cin.slice(i * d..(i + 1) * d);
        ci.copy_from_slice(wc);
        let dc = scratch.dcs.slice(i * d..(i + 1) * d);
        let wo = m.row_out_ref(o);
        let pos = dot(wc, wo);
        let g = sigmoid(pos) - 1.0;
        scratch.gp.slice(i..i + 1)[0] = g;
        let mut l = softplus(-pos) as f64;
        scale_into(g, wo, dc);
        for s in 0..k {
            let nv = pairs.negatives[i * k + s] as usize;
            let wn = m.row_out_ref(nv);
            let neg = dot(wc, wn);
            let g = sigmoid(neg);
            scratch.gn.slice(i * k + s..i * k + s + 1)[0] = g;
            l += softplus(neg) as f64;
            axpy(g, wn, dc);
        }
        scratch.loss.slice(i..i + 1)[0] = l;
    }
}

/// Sharded phase 2: thread `t` scans every pair in batch order and
/// applies the updates whose destination rows it owns. All operands come
/// from phase-1 scratch, so the write sequence per row is a pure function
/// of the batch — independent of thread count and schedule.
///
/// # Safety
/// Caller must run phase 1 to completion first (full barrier) and give
/// each thread a distinct `t < t_count`.
unsafe fn sharded_apply_owned(
    m: &EmbeddingMatrix,
    pairs: PairBatch<'_>,
    k: usize,
    lr: f32,
    t_count: usize,
    t: usize,
    reads: ShardReads<'_>,
) {
    let d = m.dim();
    let b = pairs.len();
    for i in 0..b {
        let c = pairs.centers[i] as usize;
        let o = pairs.positives[i] as usize;
        let ci = &reads.cin[i * d..(i + 1) * d];
        if shard_owner(o, t_count) == t {
            axpy(-lr * reads.gp[i], ci, m.row_out_mut(o));
        }
        for s in 0..k {
            let nv = pairs.negatives[i * k + s] as usize;
            if shard_owner(nv, t_count) == t {
                axpy(-lr * reads.gn[i * k + s], ci, m.row_out_mut(nv));
            }
        }
        if shard_owner(c, t_count) == t {
            axpy(-lr, &reads.dcs[i * d..(i + 1) * d], m.row_in_mut(c));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::RustSgns;
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn matrix_init_matches_oracle_bitwise() {
        let oracle = RustSgns::new(37, 8, 99);
        let m = EmbeddingMatrix::new(37, 8, 99);
        assert_eq!(m.w_in(), &oracle.w_in[..]);
        assert_eq!(m.w_out(), &oracle.w_out[..]);
    }

    fn toy_batch(n: usize, b: usize, k: usize, seed: u64) -> (Vec<i32>, Vec<i32>, Vec<i32>) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let draw = |rng: &mut Xoshiro256pp| rng.next_index(n) as i32;
        let centers: Vec<i32> = (0..b).map(|_| draw(&mut rng)).collect();
        let positives: Vec<i32> = (0..b)
            .map(|i| {
                // Avoid degenerate self-pairs so loss terms stay generic.
                let mut p = draw(&mut rng);
                while p == centers[i] {
                    p = draw(&mut rng);
                }
                p
            })
            .collect();
        let negatives: Vec<i32> = (0..b * k).map(|_| draw(&mut rng)).collect();
        (centers, positives, negatives)
    }

    #[test]
    fn single_thread_step_bit_identical_to_oracle() {
        let n = 50;
        let mut oracle = RustSgns::new(n, 16, 7);
        let mut par = ParallelSgns::new(n, 16, 7, 1, TrainMode::Hogwild);
        for round in 0..5u64 {
            let (c, p, neg) = toy_batch(n, 32, 5, 100 + round);
            let a = oracle.step(&c, &p, &neg, 0.1);
            let b = par.step(&c, &p, &neg, 0.1);
            assert_eq!(a, b, "loss diverged at round {round}");
        }
        assert_eq!(par.embeddings_flat(), &oracle.w_in[..]);
        assert_eq!(par.matrix.w_out(), &oracle.w_out[..]);
    }

    #[test]
    fn sharded_step_identical_across_thread_counts() {
        let n = 60;
        let mut models: Vec<ParallelSgns> = [1usize, 2, 3, 4]
            .iter()
            .map(|&t| ParallelSgns::new(n, 12, 13, t, TrainMode::Sharded))
            .collect();
        for round in 0..6u64 {
            let (c, p, neg) = toy_batch(n, 24, 4, 500 + round);
            let losses: Vec<f32> = models.iter_mut().map(|m| m.step(&c, &p, &neg, 0.15)).collect();
            for l in &losses[1..] {
                assert_eq!(*l, losses[0], "sharded loss depends on thread count");
            }
        }
        let reference = models[0].embeddings_flat().to_vec();
        for m in &models[1..] {
            assert_eq!(m.embeddings_flat(), &reference[..]);
            assert_eq!(m.matrix.w_out(), models[0].matrix.w_out());
        }
    }

    // Hogwild races on matrix rows by design; Miri flags them as UB, so
    // the determinism-free mode is covered by TSan/conformance instead.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn hogwild_multithread_step_trains_without_corruption() {
        let n = 40;
        let mut par = ParallelSgns::new(n, 16, 3, 4, TrainMode::Hogwild);
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for round in 0..150u64 {
            let (c, p, neg) = toy_batch(n, 64, 5, round);
            last = par.step(&c, &p, &neg, 0.2);
            assert!(last.is_finite(), "loss diverged at round {round}");
            if round == 0 {
                first = last;
            }
        }
        // Unstructured pairs still admit loss reduction (the 1:k pos/neg
        // imbalance pushes dots negative); racy updates must not stop it.
        assert!(last < first * 0.9, "no progress: {first} -> {last}");
        for x in par.embeddings_flat() {
            assert!(x.is_finite());
        }
    }

    #[test]
    fn shard_owner_partitions_vertices() {
        for threads in [1usize, 2, 3, 8] {
            let mut counts = vec![0usize; threads];
            for v in 0..1000 {
                counts[shard_owner(v, threads)] += 1;
            }
            assert_eq!(counts.iter().sum::<usize>(), 1000);
            let (mn, mx) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
            assert!(mx - mn <= 1, "unbalanced ownership at {threads} threads");
        }
    }

    #[test]
    fn stream_plumbing_constants() {
        assert_eq!(worker_stream_index(0), 0, "worker 0 must be the oracle stream");
        assert_eq!(worker_stream_index(1), 2, "index 1 is reserved for TrainerSink");
        assert_eq!(producer_count(1), 1);
        assert_eq!(producer_count(4), 1);
        assert_eq!(producer_count(8), 2);
    }
}
