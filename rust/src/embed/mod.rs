//! SGNS embedding trainer: turns walk sets into vertex embeddings.
//!
//! This is the Node2Vec optimization stage (the paper's Figure-1 "SGD"
//! slice). The batch pipeline lives here in Rust; the per-batch compute is
//! the AOT-compiled JAX/Pallas step driven through [`crate::runtime`]
//! (Python never runs at training time). A pure-Rust implementation of the
//! same math ([`RustSgns`]) serves as the oracle for the runtime path and
//! as a fallback when artifacts are absent.
//!
//! Batch construction follows word2vec/Node2Vec conventions:
//! - (center, context) pairs are drawn uniformly from walk positions with
//!   a window offset in `[-window, window] \ {0}`;
//! - negatives are drawn from the unigram(walk visit counts)^0.75 table;
//! - the learning rate decays linearly.
//!
//! Two ways in:
//! - **staged** — [`train`] / [`RustSgns::train`] /
//!   [`ParallelSgns::train`] over a complete [`Corpus`] (walks fully
//!   materialized first);
//! - **pipelined** — [`TrainerSink`] plugs into the walk engine's
//!   [`WalkSink`](crate::node2vec::WalkSink) interface and trains on each
//!   FN-Multi round's walks as the round completes, so SGNS no longer
//!   waits for the last walk and at most one round of walks is resident.
//!
//! Three backends sit behind [`SgnsBackend`]: the PJRT runtime, the
//! serial pure-Rust oracle ([`RustSgns`]), and the multi-threaded
//! [`ParallelSgns`] ([`parallel`]) that trains with all cores in
//! `hogwild` or `sharded` mode (`--train-threads` / `--train-mode`).

pub mod parallel;

pub use parallel::{EmbeddingMatrix, ParallelSgns, TrainMode};

use crate::graph::VertexId;
use crate::node2vec::{RoundStats, WalkSet, WalkSink};
use crate::pregel::checkpoint::{ByteReader, Persist};
use crate::runtime::SgnsRuntime;
use crate::util::alias::AliasTable;
use crate::util::error::Result;
use crate::util::rng::{stream, Xoshiro256pp};

/// Stream tag of all staged/pipelined batch-sampling RNGs: the staged
/// trainers draw from `stream(seed, BATCH_STREAM_TAG, 0, 0)`,
/// [`TrainerSink`] from index 1, hogwild workers `t >= 1` from `t + 1`
/// (see [`parallel::worker_stream_index`]).
pub(crate) const BATCH_STREAM_TAG: u64 = 0xBA7C;

/// Trainer configuration.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    /// Skip-gram window (paper/word2vec default 10).
    pub window: usize,
    pub steps: u32,
    pub lr_start: f32,
    pub lr_end: f32,
    pub seed: u64,
    /// Log the loss every `log_every` steps (0 = never). Each log costs a
    /// state download on the CPU PJRT plugin — keep sparse.
    pub log_every: u32,
    /// SGD worker threads. 1 trains on the serial path (bit-identical to
    /// the historical oracle); above 1 the [`ParallelSgns`] subsystem
    /// fans the step budget across a persistent worker pool fed by a
    /// batch-sampling pipeline.
    pub threads: usize,
    /// Parallel update discipline — `hogwild` (max throughput, not
    /// bit-reproducible above one thread) or `sharded` (bit-deterministic
    /// for, and identical across, any thread count). Ignored by the
    /// serial backends.
    pub mode: TrainMode,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            window: 10,
            steps: 1500,
            lr_start: 0.2,
            lr_end: 0.02,
            seed: 42,
            log_every: 100,
            threads: 1,
            mode: TrainMode::Hogwild,
        }
    }
}

/// Walk corpus prepared for batch sampling.
pub struct Corpus {
    /// Walks with ≥ 2 vertices (a pair needs two positions).
    walks: Vec<Vec<u32>>,
    /// Negative-sampling table over visit counts^0.75.
    neg_table: AliasTable,
    /// Map from table index to vertex id (only visited vertices).
    neg_vertices: Vec<u32>,
    pub num_vertices: usize,
}

impl Corpus {
    pub fn new(walks: &WalkSet, num_vertices: usize) -> Corpus {
        let mut counts = vec![0u64; num_vertices];
        for w in walks {
            for &v in w {
                counts[v as usize] += 1;
            }
        }
        let mut neg_vertices = Vec::new();
        let mut weights = Vec::new();
        for (v, &c) in counts.iter().enumerate() {
            if c > 0 {
                neg_vertices.push(v as u32);
                weights.push((c as f32).powf(0.75));
            }
        }
        let neg_table = AliasTable::new(&weights).expect("non-empty walk corpus");
        Corpus {
            walks: walks.iter().filter(|w| w.len() >= 2).cloned().collect(),
            neg_table,
            neg_vertices,
            num_vertices,
        }
    }

    /// Total training positions (for sizing step counts).
    pub fn positions(&self) -> usize {
        self.walks.iter().map(|w| w.len()).sum()
    }

    /// Bounded retries before [`Corpus::sample_pair`] accepts a
    /// degenerate draw on a pathological corpus (e.g. every walk orbiting
    /// one self-loop vertex).
    const MAX_PAIR_RESAMPLES: usize = 16;

    /// Draw one (center, positive) training pair.
    ///
    /// Degenerate `positive == center` draws (a walk revisiting the
    /// center inside the window — self-loops, backtracks — or, should a
    /// length-1 walk ever slip past the constructor's `len >= 2` filter,
    /// the positional `(ci + 1) % w.len()` fallback collapsing to `ci`)
    /// train a vertex on its own embedding and are resampled instead of
    /// emitted. After [`Corpus::MAX_PAIR_RESAMPLES`] failed draws the
    /// last non-positional candidate is accepted so a pathological
    /// corpus still terminates.
    fn sample_pair(&self, rng: &mut Xoshiro256pp, window: usize) -> (i32, i32) {
        debug_assert!(!self.walks.is_empty(), "corpus has no trainable walks");
        let mut last = (0i32, 0i32);
        for _ in 0..Self::MAX_PAIR_RESAMPLES {
            let w = &self.walks[rng.next_index(self.walks.len())];
            let ci = rng.next_index(w.len());
            // Offset in [-window, window], != 0, clamped into the walk.
            let off_mag = 1 + rng.next_index(window.max(1));
            let off = if rng.bernoulli(0.5) {
                off_mag as isize
            } else {
                -(off_mag as isize)
            };
            let pi = (ci as isize + off).clamp(0, w.len() as isize - 1) as usize;
            let pi = if pi == ci { (ci + 1) % w.len() } else { pi };
            if pi == ci {
                // Defense in depth: unreachable while the constructor
                // filters length-1 walks, but a future loosening of that
                // filter must not reintroduce self-position pairs.
                continue;
            }
            if w[pi] != w[ci] {
                return (w[ci] as i32, w[pi] as i32);
            }
            last = (w[ci] as i32, w[pi] as i32);
        }
        last
    }

    /// Fill one batch of (center, positive, negatives).
    pub fn fill_batch(
        &self,
        rng: &mut Xoshiro256pp,
        window: usize,
        centers: &mut [i32],
        positives: &mut [i32],
        negatives: &mut [i32],
    ) {
        let b = centers.len();
        let k = negatives.len() / b;
        for i in 0..b {
            let (c, p) = self.sample_pair(rng, window);
            centers[i] = c;
            positives[i] = p;
            for slot in 0..k {
                let nv = self.neg_vertices[self.neg_table.sample(rng)];
                negatives[i * k + slot] = nv as i32;
            }
        }
    }
}

/// Loss-curve entry.
#[derive(Clone, Copy, Debug)]
pub struct LossPoint {
    pub step: u32,
    pub loss: f32,
}

/// Train through the PJRT runtime (the production path).
pub fn train(
    runtime: &mut SgnsRuntime,
    corpus: &Corpus,
    cfg: &TrainConfig,
) -> Result<Vec<LossPoint>> {
    let b = runtime.variant.batch;
    let k = runtime.variant.negatives;
    let mut centers = vec![0i32; b];
    let mut positives = vec![0i32; b];
    let mut negatives = vec![0i32; b * k];
    let mut curve = Vec::new();
    let mut rng = stream(cfg.seed, BATCH_STREAM_TAG, 0, 0);
    for step in 0..cfg.steps {
        let t = step as f32 / cfg.steps.max(1) as f32;
        let lr = cfg.lr_start + (cfg.lr_end - cfg.lr_start) * t;
        corpus.fill_batch(&mut rng, cfg.window, &mut centers, &mut positives, &mut negatives);
        runtime.step_quiet(&centers, &positives, &negatives, lr)?;
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            curve.push(LossPoint {
                step,
                loss: runtime.last_loss()?,
            });
        }
    }
    Ok(curve)
}

/// Shared initializer of both embedding tables — the single source of the
/// init bit pattern, used by [`RustSgns::new`] and
/// [`EmbeddingMatrix::new`] so the parallel backend starts byte-identical
/// to the oracle.
pub(crate) fn init_tables(num_vertices: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0x5635);
    let scale = 0.5 / dim as f32;
    let mut init = || -> Vec<f32> {
        (0..num_vertices * dim)
            .map(|_| (rng.next_f64() as f32 * 2.0 - 1.0) * scale)
            .collect()
    };
    let w_in = init();
    let w_out = init();
    (w_in, w_out)
}

#[inline]
pub(crate) fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Pure-Rust SGNS with identical math — the oracle for the runtime path
/// and the fallback when `artifacts/` is absent.
pub struct RustSgns {
    pub dim: usize,
    pub w_in: Vec<f32>,
    pub w_out: Vec<f32>,
    pub num_vertices: usize,
}

impl RustSgns {
    /// Same init distribution as [`SgnsRuntime::load`] (not bit-identical:
    /// the runtime packs tables into the fused state in a different RNG
    /// order; tests compare losses statistically, not exactly).
    pub fn new(num_vertices: usize, dim: usize, seed: u64) -> RustSgns {
        let (w_in, w_out) = init_tables(num_vertices, dim, seed);
        RustSgns {
            dim,
            w_in,
            w_out,
            num_vertices,
        }
    }

    /// One SGD step; returns the mean batch loss. Runs the same
    /// `parallel::sgd_step_range` kernel as every [`ParallelSgns`]
    /// worker, so single-thread parity between the two backends is
    /// structural.
    pub fn step(&mut self, centers: &[i32], positives: &[i32], negatives: &[i32], lr: f32) -> f32 {
        let b = centers.len();
        if b == 0 {
            return 0.0;
        }
        let mut dc = vec![0f32; self.dim];
        let pairs = parallel::PairBatch::new(centers, positives, negatives);
        // SAFETY: the tables are exclusively borrowed (`&mut self`) and
        // every id in a batch is bounded by `num_vertices` (Corpus draws
        // from walk-visited vertices only).
        let total = unsafe {
            parallel::sgd_step_range(
                self.w_in.as_mut_ptr(),
                self.w_out.as_mut_ptr(),
                self.dim,
                pairs,
                lr,
                0..b,
                &mut dc,
            )
        };
        (total / b as f64) as f32
    }

    /// Train over a corpus with the same schedule as [`train`].
    pub fn train(
        &mut self,
        corpus: &Corpus,
        cfg: &TrainConfig,
        batch: usize,
        k: usize,
    ) -> Vec<LossPoint> {
        let mut centers = vec![0i32; batch];
        let mut positives = vec![0i32; batch];
        let mut negatives = vec![0i32; batch * k];
        let mut curve = Vec::new();
        let mut rng = stream(cfg.seed, BATCH_STREAM_TAG, 0, 0);
        for step in 0..cfg.steps {
            let t = step as f32 / cfg.steps.max(1) as f32;
            let lr = cfg.lr_start + (cfg.lr_end - cfg.lr_start) * t;
            corpus.fill_batch(&mut rng, cfg.window, &mut centers, &mut positives, &mut negatives);
            let loss = self.step(&centers, &positives, &negatives, lr);
            if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
                curve.push(LossPoint { step, loss });
            }
        }
        curve
    }

    pub fn embeddings(&self) -> Vec<Vec<f32>> {
        rows_from_flat(&self.w_in, self.dim)
    }

    /// Flat row-major view of the input embeddings — the zero-copy hot
    /// read path ([`nearest_flat`], [`cosine`] over `dim`-sized row
    /// slices) that [`RustSgns::embeddings`]'s row-by-row clone is not.
    pub fn embeddings_flat(&self) -> &[f32] {
        &self.w_in
    }
}

/// The SGD surface shared by the two training backends, so the pipelined
/// sink path ([`TrainerSink`]) is backend-agnostic: the pure-Rust oracle
/// and the PJRT runtime both take one (centers, positives, negatives, lr)
/// batch per call and report the mean batch loss.
pub trait SgnsBackend {
    fn sgd_step(
        &mut self,
        centers: &[i32],
        positives: &[i32],
        negatives: &[i32],
        lr: f32,
    ) -> Result<f32>;

    fn final_embeddings(&self) -> Result<Vec<Vec<f32>>>;

    /// Zero-copy flat view of the input embeddings plus the row width,
    /// for the hot read path ([`nearest_flat`]). `None` for backends that
    /// only materialize embeddings on demand (the PJRT runtime); callers
    /// fall back to [`SgnsBackend::final_embeddings`].
    fn embeddings_flat(&self) -> Option<(&[f32], usize)> {
        None
    }

    /// Checkpoint hook: flat `(w_in, w_out)` snapshots of both tables.
    /// `None` (the default) for backends whose state lives off-host (the
    /// PJRT runtime) — a [`TrainerSink`] over such a backend then resumes
    /// by deterministic replay instead of state restore.
    fn export_state(&self) -> Option<(Vec<f32>, Vec<f32>)> {
        None
    }

    /// Restore tables captured by [`SgnsBackend::export_state`].
    fn import_state(&mut self, w_in: &[f32], w_out: &[f32]) -> std::result::Result<(), String> {
        let _ = (w_in, w_out);
        Err("this backend does not support state import".into())
    }
}

/// Boxed backends forward, so callers can pick a backend at runtime
/// (e.g. the CLI's `--train-threads`) and still drive one
/// [`TrainerSink`] type.
impl<B: SgnsBackend + ?Sized> SgnsBackend for Box<B> {
    fn sgd_step(
        &mut self,
        centers: &[i32],
        positives: &[i32],
        negatives: &[i32],
        lr: f32,
    ) -> Result<f32> {
        (**self).sgd_step(centers, positives, negatives, lr)
    }

    fn final_embeddings(&self) -> Result<Vec<Vec<f32>>> {
        (**self).final_embeddings()
    }

    fn embeddings_flat(&self) -> Option<(&[f32], usize)> {
        (**self).embeddings_flat()
    }

    fn export_state(&self) -> Option<(Vec<f32>, Vec<f32>)> {
        (**self).export_state()
    }

    fn import_state(&mut self, w_in: &[f32], w_out: &[f32]) -> std::result::Result<(), String> {
        (**self).import_state(w_in, w_out)
    }
}

impl SgnsBackend for RustSgns {
    fn sgd_step(
        &mut self,
        centers: &[i32],
        positives: &[i32],
        negatives: &[i32],
        lr: f32,
    ) -> Result<f32> {
        Ok(self.step(centers, positives, negatives, lr))
    }

    fn final_embeddings(&self) -> Result<Vec<Vec<f32>>> {
        Ok(self.embeddings())
    }

    fn embeddings_flat(&self) -> Option<(&[f32], usize)> {
        Some((&self.w_in, self.dim))
    }

    fn export_state(&self) -> Option<(Vec<f32>, Vec<f32>)> {
        Some((self.w_in.clone(), self.w_out.clone()))
    }

    fn import_state(&mut self, w_in: &[f32], w_out: &[f32]) -> std::result::Result<(), String> {
        if w_in.len() != self.w_in.len() || w_out.len() != self.w_out.len() {
            return Err(format!(
                "embedding snapshot shape mismatch: got {}+{} floats, expected {}+{}",
                w_in.len(),
                w_out.len(),
                self.w_in.len(),
                self.w_out.len()
            ));
        }
        self.w_in.copy_from_slice(w_in);
        self.w_out.copy_from_slice(w_out);
        Ok(())
    }
}

impl SgnsBackend for SgnsRuntime {
    fn sgd_step(
        &mut self,
        centers: &[i32],
        positives: &[i32],
        negatives: &[i32],
        lr: f32,
    ) -> Result<f32> {
        self.step(centers, positives, negatives, lr)
    }

    fn final_embeddings(&self) -> Result<Vec<Vec<f32>>> {
        self.embeddings()
    }
}

/// [`WalkSink`] that pipelines walk rounds straight into SGNS training:
/// each completed FN-Multi round becomes a [`Corpus`] and is trained up to
/// its cumulative share of [`TrainConfig::steps`]
/// (`floor(steps·(round+1)/rounds)`) while the next round's walks are
/// still being computed — embedding no longer waits for the last walk, and
/// only one round of walks is ever resident here. A round that delivers no
/// trainable walks (e.g. a seed-scoped query whose seeds all land in other
/// rounds) defers its steps to the next non-empty round, so the full step
/// budget runs as long as *any* round carries walks.
///
/// Determinism: batches draw from one RNG stream that persists across
/// rounds, and the learning rate decays over the *global* step count, so
/// the loss trajectory is a pure function of (walks, `TrainConfig`, round
/// grouping) — feeding the same walks in the same round order staged or
/// pipelined produces bit-identical curves (pinned in
/// `tests/session.rs`).
///
/// Backend errors (PJRT only; the Rust oracle is infallible) are deferred
/// and surfaced by [`TrainerSink::finish`].
pub struct TrainerSink<B: SgnsBackend> {
    backend: B,
    cfg: TrainConfig,
    batch: usize,
    negatives: usize,
    rounds: u32,
    num_vertices: usize,
    /// Walks of the in-flight round; freed after the round trains.
    round_walks: Vec<Vec<u32>>,
    rng: Xoshiro256pp,
    global_step: u32,
    curve: Vec<LossPoint>,
    error: Option<crate::util::error::Error>,
}

impl<B: SgnsBackend> TrainerSink<B> {
    /// `rounds` must match the walk request's round count — it fixes the
    /// per-round training schedule up front.
    pub fn new(
        backend: B,
        num_vertices: usize,
        cfg: TrainConfig,
        batch: usize,
        negatives: usize,
        rounds: u32,
    ) -> TrainerSink<B> {
        assert!(rounds >= 1 && batch > 0 && negatives > 0);
        TrainerSink {
            backend,
            cfg,
            batch,
            negatives,
            rounds,
            num_vertices,
            round_walks: Vec::new(),
            // Distinct stream index from the staged trainer's batch RNG:
            // the pipelined schedule is its own reproducible trajectory.
            rng: stream(cfg.seed, BATCH_STREAM_TAG, 1, 0),
            global_step: 0,
            curve: Vec::new(),
            error: None,
        }
    }

    /// Steps that should have run once `round` finishes: a cumulative
    /// schedule, so rounds that couldn't train (no walks) roll their share
    /// forward instead of silently dropping it.
    fn target_steps_after(&self, round: u32) -> u32 {
        let r = u64::from((round + 1).min(self.rounds));
        (u64::from(self.cfg.steps) * r / u64::from(self.rounds)) as u32
    }

    pub fn loss_curve(&self) -> &[LossPoint] {
        &self.curve
    }

    pub fn steps_run(&self) -> u32 {
        self.global_step
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Surface any deferred backend error; on success hand back the
    /// trained backend and the loss curve.
    pub fn finish(self) -> Result<(B, Vec<LossPoint>)> {
        match self.error {
            Some(e) => Err(e),
            None => Ok((self.backend, self.curve)),
        }
    }
}

impl<B: SgnsBackend> WalkSink for TrainerSink<B> {
    fn on_walk(&mut self, _seed: VertexId, _round: u32, walk: &[VertexId]) {
        // A pair needs two positions; shorter walks carry no signal.
        if walk.len() >= 2 {
            self.round_walks.push(walk.to_vec());
        }
    }

    fn on_round_end(&mut self, round: u32, _stats: &RoundStats) {
        let walks = std::mem::take(&mut self.round_walks);
        if self.error.is_some() || self.global_step >= self.cfg.steps {
            return;
        }
        if walks.is_empty() {
            // Nothing to train on; this round's share stays in the
            // cumulative target and runs with the next non-empty round.
            return;
        }
        let steps = self.target_steps_after(round).saturating_sub(self.global_step);
        if steps == 0 {
            return;
        }
        let corpus = Corpus::new(&walks, self.num_vertices);
        let (b, k) = (self.batch, self.negatives);
        let mut centers = vec![0i32; b];
        let mut positives = vec![0i32; b];
        let mut negatives = vec![0i32; b * k];
        let total = self.cfg.steps.max(1);
        for _ in 0..steps {
            let t = self.global_step as f32 / total as f32;
            let lr = self.cfg.lr_start + (self.cfg.lr_end - self.cfg.lr_start) * t;
            corpus.fill_batch(
                &mut self.rng,
                self.cfg.window,
                &mut centers,
                &mut positives,
                &mut negatives,
            );
            match self.backend.sgd_step(&centers, &positives, &negatives, lr) {
                Ok(loss) => {
                    if self.cfg.log_every > 0
                        && (self.global_step % self.cfg.log_every == 0
                            || self.global_step + 1 == self.cfg.steps)
                    {
                        self.curve.push(LossPoint {
                            step: self.global_step,
                            loss,
                        });
                    }
                }
                Err(e) => {
                    self.error = Some(e);
                    return;
                }
            }
            self.global_step += 1;
        }
    }

    /// Snapshot the full trainer state — global step, batch RNG position,
    /// loss curve, and both embedding tables — so a resumed run continues
    /// the exact SGD trajectory instead of replaying every prior round's
    /// training. `None` when the backend can't export its tables (PJRT);
    /// the checkpointed driver then falls back to deterministic replay.
    fn checkpoint_blob(&mut self) -> Option<Vec<u8>> {
        if self.error.is_some() {
            return None;
        }
        let (w_in, w_out) = self.backend.export_state()?;
        let mut blob = Vec::with_capacity(64 + 4 * (w_in.len() + w_out.len()));
        self.global_step.persist(&mut blob);
        for word in self.rng.state() {
            word.persist(&mut blob);
        }
        (self.curve.len() as u64).persist(&mut blob);
        for p in &self.curve {
            p.step.persist(&mut blob);
            p.loss.persist(&mut blob);
        }
        (w_in.len() as u64).persist(&mut blob);
        for x in &w_in {
            x.persist(&mut blob);
        }
        (w_out.len() as u64).persist(&mut blob);
        for x in &w_out {
            x.persist(&mut blob);
        }
        Some(blob)
    }

    fn restore_blob(&mut self, blob: &[u8]) -> std::result::Result<(), String> {
        let mut r = ByteReader::new(blob);
        let global_step = r.u32()?;
        let mut st = [0u64; 4];
        for w in &mut st {
            *w = r.u64()?;
        }
        let curve_len = r.u64()? as usize;
        let mut curve = Vec::with_capacity(curve_len.min(1 << 20));
        for _ in 0..curve_len {
            curve.push(LossPoint {
                step: r.u32()?,
                loss: r.f32()?,
            });
        }
        let read_table = |r: &mut ByteReader<'_>| -> std::result::Result<Vec<f32>, String> {
            let len = r.u64()? as usize;
            let mut t = Vec::with_capacity(len.min(1 << 24));
            for _ in 0..len {
                t.push(r.f32()?);
            }
            Ok(t)
        };
        let w_in = read_table(&mut r)?;
        let w_out = read_table(&mut r)?;
        if !r.is_empty() {
            return Err("trailing bytes in trainer sink blob".into());
        }
        self.backend.import_state(&w_in, &w_out)?;
        self.rng = Xoshiro256pp::from_state(st);
        self.global_step = global_step;
        self.curve = curve;
        self.round_walks.clear();
        Ok(())
    }
}

#[inline]
pub(crate) fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else {
        (1.0 + x.exp()).ln()
    }
}

/// Materialize a flat row-major matrix as owned rows — the one place
/// the `Vec<Vec<f32>>` shape is ever built. Every backend keeps its
/// state flat (the zero-copy read path shared with the serving layer);
/// this is the boundary where legacy row-shaped consumers are fed.
pub fn rows_from_flat(flat: &[f32], dim: usize) -> Vec<Vec<f32>> {
    assert!(dim > 0 && flat.len() % dim == 0);
    flat.chunks_exact(dim).map(|r| r.to_vec()).collect()
}

/// Cosine similarity between two embedding rows.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let mut dot = 0f32;
    let mut na = 0f32;
    let mut nb = 0f32;
    for (x, y) in a.iter().zip(b) {
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    dot / (na.sqrt() * nb.sqrt()).max(1e-12)
}

/// Top-`k` nearest vertices to `v` by cosine similarity.
pub fn nearest(embeddings: &[Vec<f32>], v: usize, k: usize) -> Vec<(usize, f32)> {
    let mut scored: Vec<(usize, f32)> = embeddings
        .iter()
        .enumerate()
        .filter(|(u, _)| *u != v)
        .map(|(u, e)| (u, cosine(e, &embeddings[v])))
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    scored.truncate(k);
    scored
}

/// Top-`k` nearest vertices to `v` over a flat row-major embedding matrix
/// (`dim` floats per vertex) — the zero-copy counterpart of [`nearest`]
/// for [`SgnsBackend::embeddings_flat`] views: the scan touches one
/// contiguous allocation instead of a `Vec<Vec<f32>>` clone.
pub fn nearest_flat(embeddings: &[f32], dim: usize, v: usize, k: usize) -> Vec<(usize, f32)> {
    assert!(dim > 0 && embeddings.len() % dim == 0);
    let target = &embeddings[v * dim..(v + 1) * dim];
    let mut scored: Vec<(usize, f32)> = embeddings
        .chunks_exact(dim)
        .enumerate()
        .filter(|(u, _)| *u != v)
        .map(|(u, row)| (u, cosine(row, target)))
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    scored.truncate(k);
    scored
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{labeled_community_graph, LabeledConfig};
    use crate::node2vec::{FnConfig, WalkRequest, WalkSession};

    fn tiny_walks() -> (crate::util::sync::Arc<crate::graph::Graph>, WalkSet) {
        let lg = labeled_community_graph(&LabeledConfig::tiny(5));
        let cfg = FnConfig::new(1.0, 1.0, 3).with_walk_length(20);
        let session = WalkSession::builder(lg.graph.clone(), cfg).workers(4).build();
        let out = session.collect(&WalkRequest::all()).unwrap();
        (lg.graph, out.walks)
    }

    #[test]
    fn corpus_counts_and_tables() {
        let (g, walks) = tiny_walks();
        let corpus = Corpus::new(&walks, g.num_vertices());
        assert!(corpus.positions() > g.num_vertices() * 10);
        // Negatives come from visited vertices only.
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let mut c = vec![0i32; 8];
        let mut p = vec![0i32; 8];
        let mut n = vec![0i32; 8 * 5];
        corpus.fill_batch(&mut rng, 10, &mut c, &mut p, &mut n);
        for &x in c.iter().chain(&p).chain(&n) {
            assert!((x as usize) < g.num_vertices());
        }
        // Degenerate (v, v) pairs are resampled away (sample_pair); a
        // walk corpus with non-trivial structure never emits them.
        let degenerate = (0..8).filter(|&i| c[i] == p[i]).count();
        assert_eq!(degenerate, 0, "{degenerate}/8 degenerate pairs");
    }

    #[test]
    fn fill_batch_never_emits_degenerate_pairs() {
        // Regression: length-1 walks must never surface as (self, self)
        // pairs — the constructor excludes them and sample_pair guards
        // the positional fallback — and window-clamped draws on walks
        // that revisit a vertex (self-loops, backtracks) must resample
        // instead of training a vertex on its own embedding.
        let walks: WalkSet = vec![
            vec![7],                // length-1: excluded from sampling
            vec![9],                // length-1: excluded from sampling
            vec![1, 2, 3, 1, 4, 5], // revisits 1: degenerate-prone draws
            vec![3, 4, 3, 4, 3],    // two-cycle: every other draw clamps onto a revisit
        ];
        let corpus = Corpus::new(&walks, 16);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut c = vec![0i32; 64];
        let mut p = vec![0i32; 64];
        let mut n = vec![0i32; 64 * 2];
        for _ in 0..50 {
            corpus.fill_batch(&mut rng, 10, &mut c, &mut p, &mut n);
            for i in 0..c.len() {
                assert_ne!(c[i], p[i], "degenerate pair ({}, {})", c[i], p[i]);
                assert!(c[i] != 7 && c[i] != 9, "length-1 walk sampled as center");
                assert!(p[i] != 7 && p[i] != 9, "length-1 walk sampled as positive");
            }
        }
        // Negatives still cover *visited* vertices, including those only
        // seen on length-1 walks (visit counts are walk-length agnostic).
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            corpus.fill_batch(&mut rng, 10, &mut c, &mut p, &mut n);
            seen.extend(n.iter().copied());
        }
        assert!(seen.contains(&7) && seen.contains(&9));
    }

    #[test]
    fn rust_sgns_loss_decreases() {
        let (g, walks) = tiny_walks();
        let corpus = Corpus::new(&walks, g.num_vertices());
        let mut model = RustSgns::new(g.num_vertices(), 32, 7);
        let cfg = TrainConfig {
            steps: 300,
            log_every: 50,
            ..Default::default()
        };
        let curve = model.train(&corpus, &cfg, 128, 5);
        assert!(curve.len() >= 3);
        let first = curve.first().unwrap().loss;
        let last = curve.last().unwrap().loss;
        assert!(
            last < first * 0.8,
            "loss did not decrease: {first} -> {last}"
        );
    }

    #[test]
    fn trainer_sink_trains_per_round_and_is_deterministic() {
        let (g, walks) = tiny_walks();
        let n = g.num_vertices();
        let cfg = TrainConfig {
            steps: 300,
            log_every: 50,
            ..Default::default()
        };
        let run = || {
            let mut sink = TrainerSink::new(RustSgns::new(n, 16, 7), n, cfg, 64, 5, 3);
            for round in 0..3u32 {
                for (seed, w) in walks.iter().enumerate() {
                    if seed as u32 % 3 == round {
                        sink.on_walk(seed as u32, round, w);
                    }
                }
                sink.on_round_end(round, &RoundStats::default());
            }
            assert_eq!(sink.steps_run(), 300);
            sink.finish().unwrap()
        };
        let (m1, c1) = run();
        let (m2, c2) = run();
        assert!(!c1.is_empty());
        assert_eq!(c1.len(), c2.len());
        for (a, b) in c1.iter().zip(&c2) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.loss, b.loss, "pipelined training not deterministic");
        }
        assert_eq!(m1.w_in, m2.w_in);
        let (first, last) = (c1.first().unwrap().loss, c1.last().unwrap().loss);
        assert!(last < first, "pipelined loss did not decrease: {first} -> {last}");
    }

    #[test]
    fn trainer_sink_defers_steps_past_empty_rounds() {
        // Seed-scoped queries can leave whole rounds without walks; their
        // step share must roll forward, not vanish.
        let (g, walks) = tiny_walks();
        let n = g.num_vertices();
        let cfg = TrainConfig {
            steps: 90,
            log_every: 0,
            ..Default::default()
        };
        let mut sink = TrainerSink::new(RustSgns::new(n, 8, 3), n, cfg, 32, 5, 3);
        sink.on_round_end(0, &RoundStats::default()); // empty round
        assert_eq!(sink.steps_run(), 0);
        for (seed, w) in walks.iter().enumerate() {
            if seed % 3 == 1 {
                sink.on_walk(seed as u32, 1, w);
            }
        }
        sink.on_round_end(1, &RoundStats::default());
        assert_eq!(sink.steps_run(), 60, "round 0's share must defer to round 1");
        sink.on_round_end(2, &RoundStats::default()); // empty again: 30 deferred...
        for (seed, w) in walks.iter().enumerate() {
            if seed % 3 == 2 {
                sink.on_walk(seed as u32, 2, w);
            }
        }
        // ...but a later delivery (e.g. a second pass) still drains it.
        sink.on_round_end(2, &RoundStats::default());
        assert_eq!(sink.steps_run(), cfg.steps, "full budget must run");
        assert!(sink.finish().is_ok());
    }

    #[test]
    fn embeddings_capture_communities() {
        // After training, a vertex should be closer to a same-community
        // vertex than to the average other vertex.
        let lg = labeled_community_graph(&LabeledConfig::tiny(9));
        let cfg = FnConfig::new(1.0, 1.0, 3).with_walk_length(20);
        let session = WalkSession::builder(lg.graph.clone(), cfg).workers(4).build();
        let out = session.collect(&WalkRequest::all()).unwrap();
        let corpus = Corpus::new(&out.walks, lg.graph.num_vertices());
        let mut model = RustSgns::new(lg.graph.num_vertices(), 32, 3);
        let tcfg = TrainConfig {
            steps: 1200,
            log_every: 0,
            ..Default::default()
        };
        model.train(&corpus, &tcfg, 128, 5);
        // The hot read path: flat view, no row-by-row clone.
        let (emb, d) = (model.embeddings_flat(), model.dim);
        let n = emb.len() / d;
        // Average same-community vs cross-community cosine over a sample.
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let (mut same, mut cross) = (0f64, 0f64);
        let (mut ns, mut nc) = (0u32, 0u32);
        for _ in 0..4000 {
            let a = rng.next_index(n);
            let b = rng.next_index(n);
            if a == b {
                continue;
            }
            let shared = lg.labels[a].iter().any(|l| lg.labels[b].contains(l));
            let cs = cosine(&emb[a * d..(a + 1) * d], &emb[b * d..(b + 1) * d]) as f64;
            if shared {
                same += cs;
                ns += 1;
            } else {
                cross += cs;
                nc += 1;
            }
        }
        let same = same / ns as f64;
        let cross = cross / nc as f64;
        assert!(
            same > cross + 0.05,
            "communities not separated: same {same:.3} cross {cross:.3}"
        );
    }

    #[test]
    fn cosine_and_nearest_helpers() {
        let e = vec![
            vec![1.0, 0.0],
            vec![0.9, 0.1],
            vec![0.0, 1.0],
            vec![-1.0, 0.0],
        ];
        assert!((cosine(&e[0], &e[0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&e[0], &e[3]) < -0.99);
        let nn = nearest(&e, 0, 2);
        assert_eq!(nn[0].0, 1);
        assert_eq!(nn[1].0, 2);
        // The flat path ranks identically without materializing rows.
        let flat: Vec<f32> = e.iter().flatten().copied().collect();
        for v in 0..e.len() {
            assert_eq!(nearest_flat(&flat, 2, v, 3), nearest(&e, v, 3));
        }
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn runtime_and_rust_oracle_agree_on_first_step() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let (g, walks) = tiny_walks();
        let corpus = Corpus::new(&walks, g.num_vertices());
        let mut rt = crate::runtime::SgnsRuntime::load(&dir, g.num_vertices(), 99).unwrap();
        let b = rt.variant.batch;
        let k = rt.variant.negatives;
        let d = rt.variant.dim;
        let mut rust = RustSgns::new(g.num_vertices(), d, 99);
        // Align the initial tables exactly: copy the runtime's init.
        let emb0 = rt.embeddings().unwrap();
        for (v, row) in emb0.iter().enumerate() {
            rust.w_in[v * d..(v + 1) * d].copy_from_slice(row);
        }
        // w_out is not exposed; compare losses over a few steps instead of
        // exact table equality (both must track closely from the same
        // batches even with different w_out inits).
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let mut c = vec![0i32; b];
        let mut p = vec![0i32; b];
        let mut n = vec![0i32; b * k];
        let mut rt_losses = Vec::new();
        let mut rs_losses = Vec::new();
        for _ in 0..5 {
            corpus.fill_batch(&mut rng, 10, &mut c, &mut p, &mut n);
            rt_losses.push(rt.step(&c, &p, &n, 0.1).unwrap());
            rs_losses.push(rust.step(&c, &p, &n, 0.1));
        }
        for (a, b) in rt_losses.iter().zip(&rs_losses) {
            assert!(
                (a - b).abs() < 0.15,
                "runtime and oracle diverge: {rt_losses:?} vs {rs_losses:?}"
            );
        }
    }
}
