//! PJRT runtime: load the AOT-compiled SGNS train step and drive it from
//! Rust with device-resident embedding tables.
//!
//! The artifact pipeline (see `python/compile/aot.py` and
//! /opt/xla-example/README.md):
//!
//! ```text
//! jax.jit(train_step).lower(...) → StableHLO → XlaComputation → HLO TEXT
//!            (build time, python)                     artifacts/*.hlo.txt
//! HloModuleProto::from_text_file → XlaComputation → client.compile
//!            (run time, rust, this module)
//! ```
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids.
//!
//! After each `execute_b` the returned `w_in` / `w_out` buffers replace the
//! held ones, so the (V, D) tables never round-trip through the host during
//! training — only the (B,)-sized batch indices and the scalar loss do.
//!
//! The `xla` crate is only present in the offline vendor set, so the whole
//! PJRT path is gated behind the **`pjrt` cargo feature**. Without it this
//! module still compiles: manifest handling is pure Rust, and
//! [`SgnsRuntime::load`] returns an error that [`crate::exp::pipeline`]
//! catches to fall back to the pure-Rust SGNS oracle.

use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::error::{Context, Result};

/// One AOT shape variant from `artifacts/manifest.txt`.
#[derive(Clone, Debug)]
pub struct SgnsVariant {
    pub name: String,
    pub vocab: usize,
    pub dim: usize,
    pub batch: usize,
    pub negatives: usize,
    pub file: PathBuf,
}

/// Parse `artifacts/manifest.txt` (`name V D B K file` rows).
pub fn read_manifest(artifacts_dir: &Path) -> Result<Vec<SgnsVariant>> {
    let path = artifacts_dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() != 6 {
            bail!("manifest row malformed: {line}");
        }
        out.push(SgnsVariant {
            name: f[0].to_string(),
            vocab: f[1].parse().context("manifest vocab")?,
            dim: f[2].parse().context("manifest dim")?,
            batch: f[3].parse().context("manifest batch")?,
            negatives: f[4].parse().context("manifest negatives")?,
            file: artifacts_dir.join(f[5]),
        });
    }
    Ok(out)
}

/// Pick the smallest variant whose vocab covers `n` vertices.
pub fn pick_variant(variants: &[SgnsVariant], n: usize) -> Result<&SgnsVariant> {
    variants
        .iter()
        .filter(|v| v.vocab >= n)
        .min_by_key(|v| v.vocab)
        .ok_or_else(|| {
            crate::anyhow!(
                "no AOT variant covers {n} vertices (max {:?})",
                variants.iter().map(|v| v.vocab).max()
            )
        })
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use crate::bail;
    use crate::util::error::Result;

    /// The compiled train step plus the device-resident fused state.
    ///
    /// State layout (see `python/compile/model.py::train_step_fused`):
    /// row 0 = loss row (col 0 = mean batch loss), rows `1..V+1` = w_in,
    /// rows `V+1..2V+1` = w_out. A tuple root would force full-table host
    /// round-trips per step, so the computation is fused into one array.
    pub struct SgnsRuntime {
        exe: xla::PjRtLoadedExecutable,
        pub variant: SgnsVariant,
        state: xla::PjRtBuffer,
        /// Number of *real* vertices (≤ variant.vocab; the rest is padding).
        pub num_vertices: usize,
        pub steps_run: u64,
    }

    impl SgnsRuntime {
        /// Load + compile the variant that covers `num_vertices`, initialize
        /// tables with uniform(-0.5/D, 0.5/D) entries (word2vec convention)
        /// from `seed`.
        pub fn load(
            artifacts_dir: &Path,
            num_vertices: usize,
            seed: u64,
        ) -> Result<SgnsRuntime> {
            let variants = read_manifest(artifacts_dir)?;
            let variant = pick_variant(&variants, num_vertices)?.clone();
            let client = xla::PjRtClient::cpu()?;
            let proto = xla::HloModuleProto::from_text_file(
                variant
                    .file
                    .to_str()
                    .ok_or_else(|| crate::anyhow!("non-utf8 artifact path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;

            let (v, d) = (variant.vocab, variant.dim);
            let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(seed ^ 0x5635);
            let scale = 0.5 / d as f32;
            // Row 0 = loss row (zeros); then w_in rows, then w_out rows.
            // Padding rows (vertex id ≥ num_vertices) stay zero; the train
            // step never gathers or scatters them.
            let mut host = vec![0f32; (2 * v + 1) * d];
            for table in 0..2 {
                for row in 0..num_vertices {
                    let base = (1 + table * v + row) * d;
                    for x in &mut host[base..base + d] {
                        *x = (rng.next_f64() as f32 * 2.0 - 1.0) * scale;
                    }
                }
            }
            let state = client.buffer_from_host_buffer(&host, &[2 * v + 1, d], None)?;
            Ok(SgnsRuntime {
                exe,
                variant,
                state,
                num_vertices,
                steps_run: 0,
            })
        }

        /// One SGD step. Slices must match the variant's (B, K); indices must
        /// be `< num_vertices`. Returns the mean batch loss (a 4-byte partial
        /// host read — the tables never leave the device).
        pub fn step(
            &mut self,
            centers: &[i32],
            positives: &[i32],
            negatives: &[i32],
            lr: f32,
        ) -> Result<f32> {
            self.step_quiet(centers, positives, negatives, lr)?;
            self.last_loss()
        }

        /// [`SgnsRuntime::step`] without the loss read (hot loop).
        pub fn step_quiet(
            &mut self,
            centers: &[i32],
            positives: &[i32],
            negatives: &[i32],
            lr: f32,
        ) -> Result<()> {
            let b = self.variant.batch;
            let k = self.variant.negatives;
            if centers.len() != b || positives.len() != b || negatives.len() != b * k {
                bail!(
                    "batch shape mismatch: got ({}, {}, {}), variant needs B={b}, K={k}",
                    centers.len(),
                    positives.len(),
                    negatives.len()
                );
            }
            debug_assert!(centers
                .iter()
                .chain(positives)
                .chain(negatives)
                .all(|&i| (i as usize) < self.num_vertices));
            let client = self.exe.client().clone();
            let c = client.buffer_from_host_buffer(centers, &[b], None)?;
            let p = client.buffer_from_host_buffer(positives, &[b], None)?;
            let n = client.buffer_from_host_buffer(negatives, &[b, k], None)?;
            let lr_b = client.buffer_from_host_buffer(&[lr], &[], None)?;
            let mut outs = self.exe.execute_b(&[&self.state, &c, &p, &n, &lr_b])?;
            let mut row = outs
                .pop()
                .ok_or_else(|| crate::anyhow!("no execution outputs"))?;
            if row.len() != 1 {
                bail!("expected 1 fused output buffer, got {}", row.len());
            }
            self.state = row.pop().unwrap();
            self.steps_run += 1;
            Ok(())
        }

        /// Mean loss of the most recent step — state[0, 0].
        ///
        /// The CPU PJRT plugin does not implement `CopyRawToHost`, so this
        /// downloads the state literal (≈16 MB for the `base` variant). Call
        /// it every N steps for the loss curve, not per step; the training hot
        /// loop is [`SgnsRuntime::step_quiet`].
        pub fn last_loss(&self) -> Result<f32> {
            let mut cell = [0f32; 1];
            if self.state.copy_raw_to_host_sync(&mut cell, 0).is_ok() {
                return Ok(cell[0]);
            }
            let lit = self.state.to_literal_sync()?;
            let flat: Vec<f32> = lit.to_vec()?;
            Ok(flat[0])
        }

        /// Download the center-embedding table as one flat row-major
        /// buffer plus the row width — the same shape
        /// `SgnsBackend::embeddings_flat` exposes in-process and
        /// FN2VEMB1 stores on disk. One literal download, one copy.
        pub fn embeddings_flat_vec(&self) -> Result<(Vec<f32>, usize)> {
            let lit = self.state.to_literal_sync()?;
            let mut flat: Vec<f32> = lit.to_vec()?;
            let d = self.variant.dim;
            // Skip the loss row, keep the first `num_vertices` rows.
            flat.drain(..d);
            flat.truncate(self.num_vertices * d);
            Ok((flat, d))
        }

        /// Download the center-embedding table (first `num_vertices` rows).
        pub fn embeddings(&self) -> Result<Vec<Vec<f32>>> {
            let (flat, d) = self.embeddings_flat_vec()?;
            Ok(crate::embed::rows_from_flat(&flat, d))
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt::SgnsRuntime;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::*;
    use crate::bail;
    use crate::util::error::Result;

    /// API-compatible stand-in when built without the `pjrt` feature.
    /// [`SgnsRuntime::load`] always errors, which the embedding pipeline
    /// treats as "fall back to the pure-Rust SGNS oracle"; the remaining
    /// methods exist so callers type-check and are unreachable in practice.
    pub struct SgnsRuntime {
        pub variant: SgnsVariant,
        pub num_vertices: usize,
        pub steps_run: u64,
    }

    impl SgnsRuntime {
        pub fn load(
            _artifacts_dir: &Path,
            _num_vertices: usize,
            _seed: u64,
        ) -> Result<SgnsRuntime> {
            bail!(
                "fastn2v was built without the `pjrt` feature; \
                 rebuild with `--features pjrt` (requires the offline `xla` \
                 crate) or use the pure-Rust SGNS fallback"
            )
        }

        pub fn step(
            &mut self,
            _centers: &[i32],
            _positives: &[i32],
            _negatives: &[i32],
            _lr: f32,
        ) -> Result<f32> {
            bail!("PJRT runtime unavailable (built without the `pjrt` feature)")
        }

        pub fn step_quiet(
            &mut self,
            _centers: &[i32],
            _positives: &[i32],
            _negatives: &[i32],
            _lr: f32,
        ) -> Result<()> {
            bail!("PJRT runtime unavailable (built without the `pjrt` feature)")
        }

        pub fn last_loss(&self) -> Result<f32> {
            bail!("PJRT runtime unavailable (built without the `pjrt` feature)")
        }

        pub fn embeddings_flat_vec(&self) -> Result<(Vec<f32>, usize)> {
            bail!("PJRT runtime unavailable (built without the `pjrt` feature)")
        }

        pub fn embeddings(&self) -> Result<Vec<Vec<f32>>> {
            let (flat, d) = self.embeddings_flat_vec()?;
            Ok(crate::embed::rows_from_flat(&flat, d))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::SgnsRuntime;

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.txt").exists()
    }

    #[test]
    fn manifest_parses_and_picks() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let vs = read_manifest(&artifacts_dir()).unwrap();
        assert!(vs.len() >= 2);
        let tiny = pick_variant(&vs, 100).unwrap();
        assert_eq!(tiny.name, "tiny");
        let base = pick_variant(&vs, 10_000).unwrap();
        assert_eq!(base.name, "base");
        assert!(pick_variant(&vs, 10_000_000).is_err());
    }

    #[test]
    fn manifest_rows_validated() {
        let dir = std::env::temp_dir().join(format!("fn2v-rt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "# comment\ntiny 1000 64 128 5 tiny.hlo.txt\nbad row\n",
        )
        .unwrap();
        assert!(read_manifest(&dir).is_err());
        std::fs::write(dir.join("manifest.txt"), "tiny 1000 64 128 5 tiny.hlo.txt\n")
            .unwrap();
        let vs = read_manifest(&dir).unwrap();
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].vocab, 1000);
        assert_eq!(vs[0].negatives, 5);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_reports_missing_feature() {
        let e = SgnsRuntime::load(&artifacts_dir(), 10, 1).unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn runtime_loads_and_loss_decreases() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = SgnsRuntime::load(&artifacts_dir(), 500, 42).unwrap();
        let b = rt.variant.batch;
        let k = rt.variant.negatives;
        // A fixed positive pair per slot + random negatives: loss must drop.
        let centers: Vec<i32> = (0..b as i32).map(|i| i % 100).collect();
        let positives: Vec<i32> = centers.iter().map(|c| (c + 100) % 500).collect();
        let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(7);
        let mut first = None;
        let mut last = 0f32;
        for _ in 0..30 {
            let negs: Vec<i32> = (0..b * k)
                .map(|_| 200 + rng.next_bounded(300) as i32)
                .collect();
            last = rt.step(&centers, &positives, &negs, 0.25).unwrap();
            first.get_or_insert(last);
        }
        let first = first.unwrap();
        assert!(
            last < first * 0.8,
            "loss did not decrease: {first} -> {last}"
        );
        let emb = rt.embeddings().unwrap();
        assert_eq!(emb.len(), 500);
        assert_eq!(emb[0].len(), rt.variant.dim);
        assert!(emb.iter().flatten().all(|x| x.is_finite()));
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn batch_shape_mismatch_rejected() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = SgnsRuntime::load(&artifacts_dir(), 100, 1).unwrap();
        assert!(rt.step(&[0, 1], &[1, 2], &[1, 2, 3], 0.1).is_err());
    }
}
