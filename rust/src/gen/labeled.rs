//! Labeled-community graph generator — the BlogCatalog analogue used for
//! the node-classification experiment (paper Figure 6).
//!
//! BlogCatalog is a 10.3K-vertex social network whose vertices carry one or
//! more of 39 topic labels. The paper uses it to show that walk *quality*
//! (exact vs trimmed vs approximate 2nd-order walks) shows up directly in
//! downstream micro/macro-F1. To reproduce that, the analogue needs labels
//! *correlated with graph structure*; we use an overlapping-community
//! planted-partition model:
//!
//! - `num_communities` communities with power-law-ish sizes;
//! - each vertex joins 1..=3 communities (Zipf over count);
//! - each vertex draws edges: with prob `p_in` to a uniform member of one
//!   of its communities, else to a uniform random vertex;
//! - labels = community memberships.
//!
//! Embeddings that capture the walk neighborhood can recover community
//! membership; embeddings from trimmed walks (Spark-Node2Vec's 30-edge cap)
//! lose it — the Figure-6 effect.

use crate::graph::{Graph, GraphBuilder, VertexId};
use crate::util::rng::stream;

/// Configuration for [`labeled_community_graph`].
#[derive(Clone, Copy, Debug)]
pub struct LabeledConfig {
    pub num_vertices: usize,
    pub num_communities: usize,
    /// Average degree (BlogCatalog: 2|E|/|V| ≈ 64.8).
    pub avg_degree: usize,
    /// Probability an edge endpoint is drawn from a shared community.
    pub p_in: f64,
    pub seed: u64,
}

impl LabeledConfig {
    /// BlogCatalog-scale defaults (10.3K vertices, 39 labels, ⟨d⟩≈65).
    pub fn blogcatalog_like(seed: u64) -> Self {
        LabeledConfig {
            num_vertices: 10_312,
            num_communities: 39,
            avg_degree: 64,
            p_in: 0.8,
            seed,
        }
    }

    /// A small variant for unit tests and the quickstart example.
    pub fn tiny(seed: u64) -> Self {
        LabeledConfig {
            num_vertices: 600,
            num_communities: 6,
            avg_degree: 16,
            p_in: 0.85,
            seed,
        }
    }
}

/// A graph plus multi-label ground truth. The graph is `Arc`-shared so a
/// [`WalkSession`](crate::node2vec::WalkSession) can own it directly
/// (plain `&lg.graph` callers keep working through deref coercion).
#[derive(Clone, Debug)]
pub struct LabeledGraph {
    pub graph: crate::util::sync::Arc<Graph>,
    /// `labels[v]` = sorted community ids of vertex `v` (non-empty).
    pub labels: Vec<Vec<u16>>,
    pub num_labels: usize,
}

impl LabeledGraph {
    /// Binary indicator matrix row for vertex `v` (len = num_labels).
    pub fn label_row(&self, v: VertexId) -> Vec<f32> {
        let mut row = vec![0f32; self.num_labels];
        for &l in &self.labels[v as usize] {
            row[l as usize] = 1.0;
        }
        row
    }
}

/// Generate the labeled community graph described in the module docs.
pub fn labeled_community_graph(cfg: &LabeledConfig) -> LabeledGraph {
    assert!(cfg.num_communities >= 2);
    assert!((0.0..=1.0).contains(&cfg.p_in));
    let n = cfg.num_vertices;
    let c = cfg.num_communities;
    let mut rng = stream(cfg.seed, 0xC0, 0xFFEE, 0x1);

    // Community sizes ∝ 1/(rank+1): community 0 largest (power-law-ish,
    // mirroring BlogCatalog's imbalanced topics).
    // Assign each vertex 1..=3 communities, weighted toward 1.
    let mut labels: Vec<Vec<u16>> = Vec::with_capacity(n);
    let comm_weights: Vec<f32> = (0..c).map(|i| 1.0 / (i as f32 + 1.0)).collect();
    let comm_table =
        crate::util::alias::AliasTable::new(&comm_weights).expect("community weights");
    for _ in 0..n {
        let k = match rng.next_f64() {
            x if x < 0.70 => 1,
            x if x < 0.93 => 2,
            _ => 3,
        };
        let mut ls: Vec<u16> = Vec::with_capacity(k);
        while ls.len() < k {
            let l = comm_table.sample(&mut rng) as u16;
            if !ls.contains(&l) {
                ls.push(l);
            }
        }
        ls.sort_unstable();
        labels.push(ls);
    }

    // Heavy-tailed per-vertex "activity" so the analogue reproduces
    // BlogCatalog's degree skew (paper Table 1: max degree 3,854 ≈ 60× the
    // average). Pareto(α=1.5) capped at 100× the median.
    let activity: Vec<f32> = (0..n)
        .map(|_| {
            let u = rng.next_f64().max(1e-12);
            (u.powf(-1.0 / 1.5) as f32).min(100.0)
        })
        .collect();

    // Membership lists per community.
    let mut members: Vec<Vec<VertexId>> = vec![Vec::new(); c];
    for (v, ls) in labels.iter().enumerate() {
        for &l in ls {
            members[l as usize].push(v as VertexId);
        }
    }
    // Guard: a community could be empty at tiny n; backfill with vertex 0.
    for m in members.iter_mut() {
        if m.is_empty() {
            m.push(0);
        }
    }

    // Alias tables: global activity, and per-community member activity, so
    // both endpoints follow the heavy tail while respecting communities.
    let global_table =
        crate::util::alias::AliasTable::new(&activity).expect("activity weights");
    let member_tables: Vec<crate::util::alias::AliasTable> = members
        .iter()
        .map(|m| {
            let w: Vec<f32> = m.iter().map(|&v| activity[v as usize]).collect();
            crate::util::alias::AliasTable::new(&w).expect("member weights")
        })
        .collect();

    let num_edges = (n * cfg.avg_degree) / 2;
    let mut b = GraphBuilder::new_undirected(n).dedup_keep_first();
    b.reserve(num_edges);
    let mut placed = 0usize;
    let mut attempts = 0usize;
    while placed < num_edges && attempts < num_edges * 20 {
        attempts += 1;
        let u = global_table.sample(&mut rng) as VertexId;
        let v = if rng.bernoulli(cfg.p_in) {
            // Within one of u's communities, weighted by activity.
            let ls = &labels[u as usize];
            let l = ls[rng.next_index(ls.len())] as usize;
            members[l][member_tables[l].sample(&mut rng)]
        } else {
            global_table.sample(&mut rng) as VertexId
        };
        if u == v {
            continue;
        }
        b.add_edge(u, v, 1.0);
        placed += 1;
    }
    LabeledGraph {
        graph: b.build_shared(),
        labels,
        num_labels: c,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_vertex_is_labeled() {
        let lg = labeled_community_graph(&LabeledConfig::tiny(5));
        assert_eq!(lg.labels.len(), 600);
        assert!(lg.labels.iter().all(|ls| !ls.is_empty() && ls.len() <= 3));
        assert!(lg
            .labels
            .iter()
            .all(|ls| ls.iter().all(|&l| (l as usize) < lg.num_labels)));
    }

    #[test]
    fn label_rows_are_indicators() {
        let lg = labeled_community_graph(&LabeledConfig::tiny(5));
        let row = lg.label_row(0);
        assert_eq!(row.len(), lg.num_labels);
        let ones = row.iter().filter(|&&x| x == 1.0).count();
        assert_eq!(ones, lg.labels[0].len());
    }

    #[test]
    fn graph_has_community_structure() {
        // Edges should be far more likely within a shared community than
        // between unrelated vertices.
        let lg = labeled_community_graph(&LabeledConfig::tiny(7));
        let g = &lg.graph;
        let mut intra = 0usize;
        let mut total = 0usize;
        for u in g.vertices() {
            for &v in g.neighbors(u) {
                if v < u {
                    continue;
                }
                total += 1;
                let shared = lg.labels[u as usize]
                    .iter()
                    .any(|l| lg.labels[v as usize].contains(l));
                if shared {
                    intra += 1;
                }
            }
        }
        let frac = intra as f64 / total as f64;
        assert!(frac > 0.6, "intra-community fraction only {frac}");
    }

    #[test]
    fn blogcatalog_scale_matches_table1() {
        let lg = labeled_community_graph(&LabeledConfig::blogcatalog_like(1));
        let s = lg.graph.stats();
        assert_eq!(s.num_vertices, 10_312);
        assert_eq!(lg.num_labels, 39);
        // Table 1: 334.0K edges => avg degree ~64.8. Allow dedup slack.
        assert!(s.avg_degree > 50.0 && s.avg_degree < 70.0, "{}", s.avg_degree);
        // Degrees are skewed (paper max degree 3,854) — check heavy tail
        // exists at our scale.
        assert!(
            s.max_degree as f64 > 6.0 * s.avg_degree,
            "max {} vs avg {}",
            s.max_degree,
            s.avg_degree
        );
    }

    #[test]
    fn deterministic_in_seed() {
        let a = labeled_community_graph(&LabeledConfig::tiny(9));
        let b = labeled_community_graph(&LabeledConfig::tiny(9));
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.graph.num_arcs(), b.graph.num_arcs());
    }
}
