//! RMAT recursive-matrix graph generation (Chakrabarti et al., 2004), in the
//! recursive-vector style TrillionG uses: each edge is placed by descending
//! K levels of the 2^K × 2^K adjacency matrix, choosing one of the four
//! quadrants with probabilities (a, b, c, d) at every level.
//!
//! Parameterizations from the paper (§4.1):
//! - **ER-K**:   (0.25, 0.25, 0.25, 0.25), avg degree 10 — uniform, no skew.
//! - **WeC-K**:  (0.18, 0.25, 0.25, 0.32), avg degree 100 — WeChat-like.
//! - **Skew-S**: b = c = 0.25, d = S·a, a + d = 0.5, avg degree 100 —
//!   skew dial; Skew-1 is uniform, larger S is closer to power-law.
//!   (WeC-K is Skew-1.78: 0.32/0.18.)
//!
//! Edge generation is multi-threaded with per-chunk RNG streams, so output
//! is deterministic in the seed and independent of thread count.

use crate::graph::{Graph, GraphBuilder, VertexId};
use crate::util::rng::{stream, Xoshiro256pp};

/// Quadrant probabilities.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub d: f64,
}

impl RmatParams {
    pub fn new(a: f64, b: f64, c: f64, d: f64) -> Self {
        let sum = a + b + c + d;
        assert!((sum - 1.0).abs() < 1e-9, "RMAT params must sum to 1, got {sum}");
        assert!(a >= 0.0 && b >= 0.0 && c >= 0.0 && d >= 0.0);
        RmatParams { a, b, c, d }
    }

    pub fn uniform() -> Self {
        RmatParams::new(0.25, 0.25, 0.25, 0.25)
    }

    /// WeC parameters from the paper.
    pub fn wec() -> Self {
        RmatParams::new(0.18, 0.25, 0.25, 0.32)
    }

    /// Skew-S: b = c = 0.25, d = S·a, a + d = 0.5.
    pub fn skew(s: f64) -> Self {
        assert!(s >= 1.0, "skew S must be >= 1");
        let a = 0.5 / (1.0 + s);
        let d = s * a;
        RmatParams::new(a, 0.25, 0.25, d)
    }
}

/// Common generator configuration.
#[derive(Clone, Copy, Debug)]
pub struct GenConfig {
    /// Number of vertices (need not be a power of two; edges landing
    /// outside `[0, n)` are re-drawn).
    pub num_vertices: usize,
    /// Target *average* degree (undirected): we draw `n * avg_degree / 2`
    /// edges before dedup.
    pub avg_degree: usize,
    pub seed: u64,
}

impl GenConfig {
    pub fn new(num_vertices: usize, avg_degree: usize, seed: u64) -> Self {
        assert!(num_vertices > 1);
        GenConfig {
            num_vertices,
            avg_degree,
            seed,
        }
    }
}

/// Place one endpoint pair by recursive quadrant descent.
#[inline]
fn place_edge(
    levels: u32,
    p: &RmatParams,
    rng: &mut Xoshiro256pp,
) -> (u64, u64) {
    let mut row = 0u64;
    let mut col = 0u64;
    // Cumulative thresholds.
    let t_a = p.a;
    let t_ab = p.a + p.b;
    let t_abc = p.a + p.b + p.c;
    for level in (0..levels).rev() {
        let r = rng.next_f64();
        let bit = 1u64 << level;
        if r < t_a {
            // top-left
        } else if r < t_ab {
            col |= bit;
        } else if r < t_abc {
            row |= bit;
        } else {
            row |= bit;
            col |= bit;
        }
    }
    (row, col)
}

/// Generate an undirected RMAT graph with `num_edges` drawn edges (before
/// dedup/self-loop removal) over `cfg.num_vertices` vertices.
pub fn rmat_graph_edges(
    cfg: &GenConfig,
    params: RmatParams,
    num_edges: u64,
) -> Graph {
    let n = cfg.num_vertices as u64;
    let levels = (64 - (n - 1).leading_zeros()).max(1);
    let nthreads = crate::util::sync::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(16)
        .max(1);
    // Deterministic chunking: fixed chunk count regardless of nthreads.
    let chunks: u64 = 64;
    let per_chunk = num_edges.div_ceil(chunks);
    let chunk_edges: Vec<Vec<(VertexId, VertexId)>> = crate::util::sync::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..nthreads as u64 {
            let params = params;
            handles.push(scope.spawn(move || {
                let mut out: Vec<(u64, Vec<(VertexId, VertexId)>)> = Vec::new();
                let mut chunk = t;
                while chunk < chunks {
                    let todo = per_chunk.min(num_edges.saturating_sub(chunk * per_chunk));
                    let mut rng = stream(cfg.seed, chunk, 0xE06E, 0x6E4);
                    let mut edges = Vec::with_capacity(todo as usize);
                    for _ in 0..todo {
                        // Rejection-sample until both endpoints are in range
                        // and the edge is not a self-loop.
                        loop {
                            let (r, c) = place_edge(levels, &params, &mut rng);
                            if r < n && c < n && r != c {
                                edges.push((r as VertexId, c as VertexId));
                                break;
                            }
                        }
                    }
                    out.push((chunk, edges));
                    chunk += nthreads as u64;
                }
                out
            }));
        }
        let mut all: Vec<(u64, Vec<(VertexId, VertexId)>)> = Vec::new();
        for h in handles {
            all.extend(h.join().expect("generator thread panicked"));
        }
        // Restore deterministic chunk order.
        all.sort_by_key(|(c, _)| *c);
        all.into_iter().map(|(_, e)| e).collect()
    });

    let total: usize = chunk_edges.iter().map(|c| c.len()).sum();
    let mut b = GraphBuilder::new_undirected(cfg.num_vertices).dedup_keep_first();
    b.reserve(total);
    for chunk in chunk_edges {
        for (u, v) in chunk {
            b.add_edge(u, v, 1.0);
        }
    }
    b.build()
}

/// RMAT with edge count derived from the target average degree.
pub fn rmat_graph(cfg: &GenConfig, params: RmatParams) -> Graph {
    let num_edges = (cfg.num_vertices as u64 * cfg.avg_degree as u64) / 2;
    rmat_graph_edges(cfg, params, num_edges)
}

/// ER-K analogue: uniform RMAT (paper: avg degree 10).
pub fn er_graph(cfg: &GenConfig) -> Graph {
    rmat_graph(cfg, RmatParams::uniform())
}

/// WeC-K analogue (paper: avg degree 100, max-degree cap ~5000 at 2^K
/// scale; the cap emerges from the parameters rather than being enforced).
pub fn wec_graph(cfg: &GenConfig) -> Graph {
    rmat_graph(cfg, RmatParams::wec())
}

/// Skew-S graph (paper: 2^22 vertices, avg degree 100, S in 1..=5).
pub fn skew_graph(cfg: &GenConfig, s: f64) -> Graph {
    rmat_graph(cfg, RmatParams::skew(s))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skew_params_match_paper_constraints() {
        for s in [1.0, 1.78, 2.0, 3.0, 4.0, 5.0] {
            let p = RmatParams::skew(s);
            assert!((p.b - 0.25).abs() < 1e-12);
            assert!((p.c - 0.25).abs() < 1e-12);
            assert!((p.d - s * p.a).abs() < 1e-9, "d != S*a for S={s}");
            assert!((p.a + p.b + p.c + p.d - 1.0).abs() < 1e-9);
        }
        // Skew-1 is uniform.
        let p1 = RmatParams::skew(1.0);
        assert!((p1.a - 0.25).abs() < 1e-12 && (p1.d - 0.25).abs() < 1e-12);
        // WeC is Skew-1.78 (0.32/0.18).
        let w = RmatParams::wec();
        assert!((w.d / w.a - 1.7777).abs() < 1e-3);
    }

    #[test]
    #[should_panic(expected = "sum to 1")]
    fn bad_params_rejected() {
        RmatParams::new(0.5, 0.5, 0.5, 0.5);
    }

    #[test]
    fn er_degree_is_concentrated() {
        let cfg = GenConfig::new(1 << 12, 10, 42);
        let g = er_graph(&cfg);
        let s = g.stats();
        assert_eq!(s.num_vertices, 1 << 12);
        // avg degree ~10 (slightly less after dedup)
        assert!(s.avg_degree > 8.0 && s.avg_degree < 10.5, "{}", s.avg_degree);
        // Uniform graphs have low max degree (paper Table 1: 29-35).
        assert!(s.max_degree < 40, "max degree {}", s.max_degree);
    }

    #[test]
    fn skew_increases_max_degree() {
        let cfg = GenConfig::new(1 << 12, 20, 7);
        let g1 = skew_graph(&cfg, 1.0);
        let g3 = skew_graph(&cfg, 3.0);
        let g5 = skew_graph(&cfg, 5.0);
        let (m1, m3, m5) = (
            g1.stats().max_degree,
            g3.stats().max_degree,
            g5.stats().max_degree,
        );
        assert!(m1 < m3 && m3 < m5, "skew ordering violated: {m1} {m3} {m5}");
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = GenConfig::new(1000, 8, 123);
        let g1 = wec_graph(&cfg);
        let g2 = wec_graph(&cfg);
        assert_eq!(g1.num_arcs(), g2.num_arcs());
        for v in g1.vertices() {
            assert_eq!(g1.neighbors(v), g2.neighbors(v));
        }
    }

    #[test]
    fn different_seeds_differ() {
        let g1 = er_graph(&GenConfig::new(1000, 8, 1));
        let g2 = er_graph(&GenConfig::new(1000, 8, 2));
        let same = g1
            .vertices()
            .all(|v| g1.neighbors(v) == g2.neighbors(v));
        assert!(!same);
    }

    #[test]
    fn no_self_loops_and_symmetric() {
        let g = skew_graph(&GenConfig::new(512, 16, 99), 4.0);
        for v in g.vertices() {
            for &u in g.neighbors(v) {
                assert_ne!(u, v, "self loop at {v}");
                assert!(g.has_edge(u, v));
            }
        }
    }

    #[test]
    fn non_power_of_two_vertex_count() {
        let g = er_graph(&GenConfig::new(1000, 6, 5));
        assert_eq!(g.num_vertices(), 1000);
        let max_id = g
            .vertices()
            .flat_map(|v| g.neighbors(v).iter().copied())
            .max()
            .unwrap();
        assert!(max_id < 1000);
    }
}
