//! Scaled analogues of the paper's real-world SNAP graphs (Table 1).
//!
//! SNAP downloads are unavailable offline, so each graph is an RMAT
//! parameterization matched on the two properties that drive every result
//! in the paper — average degree and degree-distribution skew — at roughly
//! 1/40–1/100 of the original vertex count so the full figure sweeps run in
//! minutes on one machine. `name` and `paper_*` fields keep the provenance
//! visible in printed tables.

use super::rmat::{rmat_graph, GenConfig, RmatParams};
use crate::graph::Graph;

/// A generated analogue plus the paper's original statistics for reporting.
#[derive(Clone, Debug)]
pub struct RealWorldAnalogue {
    pub name: &'static str,
    pub paper_vertices: &'static str,
    pub paper_edges: &'static str,
    pub paper_max_degree: u64,
    pub graph: Graph,
}

/// Scale factor applied to vertex counts (1 = paper scale). The default
/// drivers use `scale_denominator = 40` for the small graphs and more for
/// Friendster.
fn scaled(n: usize, denom: usize) -> usize {
    (n / denom).max(1024)
}

/// com-LiveJournal analogue: 4.0M vertices, 34.7M edges (⟨d⟩≈17.3,
/// max 14,815 ⇒ max/avg ≈ 855 ⇒ strong skew).
pub fn livejournal_like(seed: u64, denom: usize) -> RealWorldAnalogue {
    let cfg = GenConfig::new(scaled(4_000_000, denom), 17, seed);
    RealWorldAnalogue {
        name: "com-LiveJournal~",
        paper_vertices: "4.0M",
        paper_edges: "34.7M",
        paper_max_degree: 14_815,
        graph: rmat_graph(&cfg, RmatParams::skew(4.0)),
    }
}

/// com-Orkut analogue: 3.1M vertices, 117.2M edges (⟨d⟩≈75.6, max 58,999).
pub fn orkut_like(seed: u64, denom: usize) -> RealWorldAnalogue {
    let cfg = GenConfig::new(scaled(3_100_000, denom), 75, seed);
    RealWorldAnalogue {
        name: "com-Orkut~",
        paper_vertices: "3.1M",
        paper_edges: "117.2M",
        paper_max_degree: 58_999,
        graph: rmat_graph(&cfg, RmatParams::skew(4.0)),
    }
}

/// com-Friendster analogue: 65.6M vertices, 1.8G edges (⟨d⟩≈55, max 8,447
/// ⇒ milder skew than Orkut).
pub fn friendster_like(seed: u64, denom: usize) -> RealWorldAnalogue {
    let cfg = GenConfig::new(scaled(65_600_000, denom), 55, seed);
    RealWorldAnalogue {
        name: "com-Friendster~",
        paper_vertices: "65.6M",
        paper_edges: "1.8G",
        paper_max_degree: 8_447,
        graph: rmat_graph(&cfg, RmatParams::skew(2.5)),
    }
}

/// BlogCatalog analogue at full paper scale (10.3K vertices); the labeled
/// variant for Figure 6 lives in [`super::labeled_community_graph`] — this
/// one is for the pure-efficiency Figure 7(a).
pub fn blogcatalog_like(seed: u64) -> RealWorldAnalogue {
    let lg = super::labeled::labeled_community_graph(
        &super::labeled::LabeledConfig::blogcatalog_like(seed),
    );
    RealWorldAnalogue {
        name: "BlogCatalog~",
        paper_vertices: "10.3K",
        paper_edges: "334.0K",
        paper_max_degree: 3_854,
        graph: lg.graph,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analogues_have_expected_scale_and_skew() {
        let lj = livejournal_like(3, 100);
        let s = lj.graph.stats();
        assert_eq!(s.num_vertices, 40_000);
        assert!(s.avg_degree > 12.0 && s.avg_degree < 18.0, "{}", s.avg_degree);
        assert!(
            s.max_degree as f64 > 10.0 * s.avg_degree,
            "skew missing: max {} avg {}",
            s.max_degree,
            s.avg_degree
        );
    }

    #[test]
    fn orkut_denser_than_livejournal() {
        let lj = livejournal_like(3, 200);
        let ok = orkut_like(3, 200);
        assert!(
            ok.graph.stats().avg_degree > 3.0 * lj.graph.stats().avg_degree,
            "paper: Orkut avg degree is 4.3x LiveJournal's"
        );
    }

    #[test]
    fn scaled_floors_at_1024() {
        assert_eq!(scaled(10_000, 1000), 1024);
    }
}
