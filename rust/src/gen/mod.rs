//! Synthetic graph generators.
//!
//! The paper evaluates on (a) SNAP real-world graphs and (b) RMAT-generated
//! synthetic families (ER-K, WeC-K, Skew-S) produced with TrillionG. SNAP
//! downloads are unavailable in this offline environment, so `realworld`
//! provides RMAT-parameterized *analogues* scaled down ~40–100× but matched
//! on the properties that drive the paper's results (average degree and
//! degree skew). See DESIGN.md §Substitutions.

mod labeled;
mod rmat;
pub mod realworld;

pub use labeled::{labeled_community_graph, LabeledConfig, LabeledGraph};
pub use rmat::{er_graph, rmat_graph, skew_graph, wec_graph, GenConfig, RmatParams};
