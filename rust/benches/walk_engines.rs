//! End-to-end walk-engine comparison (the paper's Figure 7/13 axis): all
//! FN variants plus both baselines on a skewed R-MAT graph, reported as
//! wall time and steps/second — plus a linear-vs-rejection sampler
//! head-to-head, a partitioning ablation (hash / range / degree-aware ×
//! hot-vertex splitting, EXPERIMENTS.md §Partitioning), the SGNS
//! trainer throughput grid (threads × {hogwild, sharded},
//! EXPERIMENTS.md §Train), the checkpoint overhead/resume-latency
//! pair (EXPERIMENTS.md §Robustness), the shard-per-process fleet
//! overhead at 1/2/4 shards (EXPERIMENTS.md §Distributed) and the
//! serving stack — FN2VEMB1 write/open, HNSW build + recall@10,
//! brute-force vs indexed query latency and a daemon batch-size sweep
//! (EXPERIMENTS.md §Serve) — all recorded as a machine-readable
//! baseline in `BENCH_walks.json` for future PRs.
//!
//! Run: `cargo bench --bench walk_engines`
//! (FASTN2V_BENCH_FULL=1 for a larger graph; FASTN2V_BENCH_OUT to move the
//! JSON baseline, default `../BENCH_walks.json` next to EXPERIMENTS.md;
//! `-- --quick` for the CI smoke run: tiny graph, JSON write skipped
//! unless FASTN2V_BENCH_OUT is set.)

use fastn2v::coordinator::DistConfig;
use fastn2v::embed::{Corpus, ParallelSgns, TrainConfig, TrainMode};
use fastn2v::exp::common::{popular_threshold, run_fn_with_cfg, run_solution, Solution};
use fastn2v::exp::pipeline::{
    partition_ablation, session_amortization, PartitionAblationRow, SessionAmortization,
};
use fastn2v::gen::{skew_graph, GenConfig};
use fastn2v::graph::{open_graph, write_v2, OpenOptions};
use fastn2v::node2vec::{
    CheckpointCfg, CollectSink, FnConfig, SamplerKind, SeedSet, Variant, WalkRequest, WalkSession,
};
use fastn2v::pregel::checkpoint::checkpoint_files;
use fastn2v::serve::{
    recall_at_k, run_server, write_emb, EmbStore, HnswIndex, HnswParams, ServeClient, ServeCore,
    ServeOpts, ServeRequest,
};
use fastn2v::util::benchkit::print_table;
use fastn2v::util::mmap::Mmap;

struct Row {
    name: String,
    secs: Option<f64>,
    msteps: Option<f64>,
}

/// Workers for the partitioning ablation — the tentpole acceptance
/// criterion is stated at 8 workers on rmat-skew-4.
const ABLATION_WORKERS: usize = 8;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let full = std::env::var("FASTN2V_BENCH_FULL").is_ok();
    let (n, deg, walk_len) = if full {
        (1 << 17, 100, 80u32)
    } else if quick {
        (1 << 10, 16, 6u32)
    } else {
        (1 << 13, 40, 20u32)
    };
    // R-MAT Skew-4: heavy-tailed degrees well past `popular_threshold`, the
    // regime where per-hop cost at popular vertices dominates wall time.
    let g = std::sync::Arc::new(skew_graph(&GenConfig::new(n, deg, 11), 4.0));
    let stats = g.stats();
    println!(
        "graph: |V|={} |E|={} max deg {} | walk length {walk_len}",
        stats.num_vertices, stats.num_edges, stats.max_degree
    );
    let total_steps = (stats.num_vertices * walk_len as u64) as f64;
    // FN-Reject's proposal tables are built at graph load, not inside the
    // timed region (they are shared state, not per-run work).
    let _ = g.first_order_tables();

    let mut rows: Vec<Row> = Vec::new();
    for sol in [
        Solution::CNode2Vec,
        Solution::Spark,
        Solution::Fn(Variant::Base),
        Solution::Fn(Variant::Local),
        Solution::Fn(Variant::Switch),
        Solution::Fn(Variant::Cache),
        Solution::Fn(Variant::Approx),
        Solution::Fn(Variant::Reject),
    ] {
        let out = run_solution(sol, &g, 0.5, 2.0, walk_len, 3, false);
        rows.push(Row {
            name: sol.name().to_string(),
            secs: out.secs(),
            msteps: out.secs().map(|s| total_steps / s / 1e6),
        });
    }

    // Sampler head-to-head under identical (FN-Cache) message handling, so
    // the only difference is the per-hop sampling strategy.
    for kind in [SamplerKind::Linear, SamplerKind::Reject] {
        let cfg = FnConfig::new(0.5, 2.0, 3)
            .with_walk_length(walk_len)
            .with_popular_threshold(popular_threshold(&g))
            .with_variant(Variant::Cache)
            .with_sampler(kind);
        let out = run_fn_with_cfg(&g, &cfg, false);
        rows.push(Row {
            name: format!("FN-Cache/{}", kind.name()),
            secs: out.secs(),
            msteps: out.secs().map(|s| total_steps / s / 1e6),
        });
    }

    let table: Vec<(String, Vec<String>)> = rows
        .iter()
        .map(|r| {
            let cells = match r.secs {
                Some(s) => vec![
                    fastn2v::util::fmt_secs(s),
                    format!("{:.2} M steps/s", r.msteps.unwrap()),
                ],
                None => vec!["x (OOM)".into(), "-".into()],
            };
            (r.name.clone(), cells)
        })
        .collect();
    print_table("walk engines (R-MAT skew-4 graph)", &["wall", "throughput"], &table);

    // ---- partitioning ablation: hash / range / degree × hot splitting ----
    // Hot threshold: well into the heavy tail but low enough to shard the
    // top hubs (half the max degree, floored at twice the popular cutoff).
    let hot_threshold = (g.max_degree() / 2).max(2 * popular_threshold(&g));
    let ablation_cfg = FnConfig::new(0.5, 2.0, 3)
        .with_walk_length(walk_len)
        .with_popular_threshold(popular_threshold(&g))
        .with_variant(Variant::Cache);
    let ablation = partition_ablation(&g, ABLATION_WORKERS, &ablation_cfg, hot_threshold);
    let ablation_table: Vec<(String, Vec<String>)> = ablation
        .iter()
        .map(|r| {
            (
                format!("{}{}", r.scheme, if r.hot_split { "+hot" } else { "" }),
                vec![
                    fastn2v::util::fmt_secs(r.wall_secs),
                    format!("{:.3}", r.aggregate_imbalance),
                    format!("{:.3}", r.worst_imbalance),
                    r.hot_tasks.to_string(),
                ],
            )
        })
        .collect();
    print_table(
        &format!("partitioning ablation ({ABLATION_WORKERS} workers, hot deg >= {hot_threshold})"),
        &["wall", "imbalance", "worst step", "hot tasks"],
        &ablation_table,
    );
    let imbalance_of = |scheme: &str, hot: bool| {
        ablation
            .iter()
            .find(|r| r.scheme == scheme && r.hot_split == hot)
            .map(|r| r.aggregate_imbalance)
    };
    // The acceptance criterion is stated on the max/mean compute-time
    // imbalance *ratio*: hash / (degree + hot split) >= 2x. The per-row
    // imbalance values are all in the JSON, so any derived form can be
    // recomputed; only the acceptance-aligned ratio gets a headline key.
    let ratio_reduction = match (imbalance_of("hash", false), imbalance_of("degree", true)) {
        (Some(h), Some(d)) if d > 0.0 => Some(h / d),
        _ => None,
    };
    if let Some(r) = ratio_reduction {
        println!("\nimbalance-ratio reduction, degree+hot vs hash: {r:.2}x");
    }

    // ---- session amortization: prepared WalkSession vs rebuild/query ----
    // N short seed-slice queries, the serving pattern the session API
    // exists for (EXPERIMENTS.md §API): the rebuild path pays the
    // partition plan + worker-list derivation on every query.
    let queries = if quick { 10 } else { 100 };
    let amort_cfg = FnConfig::new(0.5, 2.0, 3)
        .with_walk_length(walk_len.min(10))
        .with_popular_threshold(popular_threshold(&g))
        .with_variant(Variant::Cache);
    let amort = session_amortization(&g, ABLATION_WORKERS, &amort_cfg, queries, 64);
    println!(
        "\nsession amortization ({} queries x {} seeds): reuse {} vs rebuild {} ({:.2}x)",
        amort.queries,
        amort.seeds_per_query,
        fastn2v::util::fmt_secs(amort.reuse_secs),
        fastn2v::util::fmt_secs(amort.rebuild_secs),
        amort.speedup()
    );

    // ---- graph store: open-time + first-walk latency, mmap vs owned ----
    // The serving scenario EXPERIMENTS.md §Scale measures: how long from
    // a cold graph *file* to an open Graph, and to the first walk out of
    // a one-seed query (open + session build + query).
    let store = graph_store_bench(&g, walk_len.min(10));
    let store_table: Vec<(String, Vec<String>)> = store
        .rows
        .iter()
        .map(|r| {
            (
                r.name.to_string(),
                vec![
                    fastn2v::util::fmt_secs(r.open_secs),
                    fastn2v::util::fmt_secs(r.first_walk_secs),
                ],
            )
        })
        .collect();
    print_table(
        &format!(
            "graph store ({} FN2VGRF2 on disk{})",
            fastn2v::util::fmt_bytes(store.file_bytes),
            if store.mmap_supported {
                ""
            } else {
                "; mmap unsupported here"
            }
        ),
        &["open", "first walk"],
        &store_table,
    );

    // ---- sgns_train: parallel trainer throughput, threads × mode ----
    // The walk engine's consumer: steps/sec of the SGNS stage for both
    // update disciplines at 1/2/4/8 workers (EXPERIMENTS.md §Train).
    let sgns = sgns_train_bench(&g, walk_len.min(20), quick);
    let sgns_table: Vec<(String, Vec<String>)> = sgns
        .rows
        .iter()
        .map(|r| {
            (
                format!("{}/t{}", r.mode, r.threads),
                vec![
                    fastn2v::util::fmt_secs(r.wall_secs),
                    format!("{:.0} steps/s", r.steps_per_sec),
                    format!("{:.3}", r.final_loss),
                ],
            )
        })
        .collect();
    print_table(
        &format!(
            "sgns train ({} steps, batch {} x {} negs, dim {})",
            sgns.steps, sgns.batch, sgns.negatives, sgns.dim
        ),
        &["wall", "throughput", "final loss"],
        &sgns_table,
    );
    let sgns_of = |mode: &str, threads: usize| {
        sgns.rows
            .iter()
            .find(|r| r.mode == mode && r.threads == threads)
            .map(|r| r.steps_per_sec)
    };
    if let (Some(serial), Some(par)) = (sgns_of("hogwild", 1), sgns_of("hogwild", 8)) {
        if serial > 0.0 {
            println!("hogwild train speedup, 8 threads vs serial: {:.2}x", par / serial);
        }
    }

    // ---- checkpoint: crash-safety overhead + resume-from-mid latency ----
    // What checkpointing costs when nothing crashes (EXPERIMENTS.md
    // §Robustness), and how long a resume from a mid-run checkpoint takes.
    let ckpt = checkpoint_bench(&g, walk_len.min(20), quick);
    let ckpt_table: Vec<(String, Vec<String>)> = vec![
        (
            "plain".into(),
            vec![fastn2v::util::fmt_secs(ckpt.plain_secs), "-".into(), "-".into()],
        ),
        (
            "checkpointed".into(),
            vec![
                fastn2v::util::fmt_secs(ckpt.checkpointed_secs),
                format!("{:+.1}%", ckpt.overhead_pct()),
                format!(
                    "{} files, {} io",
                    ckpt.checkpoints_written,
                    fastn2v::util::fmt_secs(ckpt.checkpoint_io_secs)
                ),
            ],
        ),
        (
            "resume (mid ckpt)".into(),
            vec![fastn2v::util::fmt_secs(ckpt.resume_secs), "-".into(), "-".into()],
        ),
    ];
    print_table(
        &format!(
            "checkpoint (FN-Cache, every {} supersteps, {} per file)",
            ckpt.every,
            fastn2v::util::fmt_bytes(ckpt.file_bytes)
        ),
        &["wall", "vs plain", "checkpoint io"],
        &ckpt_table,
    );

    // ---- distributed: shard-per-process fleet vs single process ----
    // In-proc transport isolates the sharding overhead itself (message
    // encode/decode + barrier) from process-spawn cost; every fleet shape
    // must stay bit-identical to the plain run (EXPERIMENTS.md
    // §Distributed), so the rows are directly comparable.
    let dist = distributed_bench(&g, walk_len.min(20));
    let mut dist_table: Vec<(String, Vec<String>)> = vec![(
        "single process".into(),
        vec![fastn2v::util::fmt_secs(dist.plain_secs), "-".into(), "-".into()],
    )];
    for r in &dist.rows {
        dist_table.push((
            format!("{} shard(s)", r.shards),
            vec![
                fastn2v::util::fmt_secs(r.wall_secs),
                if dist.plain_secs > 0.0 {
                    format!("{:+.1}%", (r.wall_secs / dist.plain_secs - 1.0) * 100.0)
                } else {
                    "-".into()
                },
                fastn2v::util::fmt_bytes(r.bytes_remote),
            ],
        ));
    }
    print_table(
        &format!(
            "distributed fleet (FN-Cache, in-proc transport, {} workers/shard)",
            dist.workers_per_shard
        ),
        &["wall", "vs single", "remote bytes"],
        &dist_table,
    );

    // ---- serve: FN2VEMB1 store + HNSW + daemon batch sweep ----
    // The serving half of EXPERIMENTS.md §Serve: persist/reopen cost of
    // the embedding file (owned decode vs zero-copy mmap), HNSW build
    // time and recall@10 against the brute-force oracle, per-query NN
    // latency both ways, and daemon throughput as the batcher's drain
    // size grows.
    let serve = serve_bench(&g, quick);
    let mut serve_table: Vec<(String, Vec<String>)> = vec![
        (
            "emb write".into(),
            vec![fastn2v::util::fmt_secs(serve.write_secs), "-".into()],
        ),
        (
            "emb open (owned)".into(),
            vec![fastn2v::util::fmt_secs(serve.open_owned_secs), "-".into()],
        ),
    ];
    if let Some(s) = serve.open_mapped_secs {
        serve_table.push((
            "emb open (mmap)".into(),
            vec![fastn2v::util::fmt_secs(s), "-".into()],
        ));
    }
    serve_table.push((
        "hnsw build".into(),
        vec![
            fastn2v::util::fmt_secs(serve.hnsw_build_secs),
            format!("recall@10 {:.3}", serve.recall_at_10),
        ],
    ));
    serve_table.push((
        "nn brute".into(),
        vec![
            format!("{:.0} us p50", serve.brute_p50_us),
            format!("{:.0} us p99", serve.brute_p99_us),
        ],
    ));
    serve_table.push((
        "nn hnsw".into(),
        vec![
            format!("{:.0} us p50", serve.hnsw_p50_us),
            format!("{:.0} us p99", serve.hnsw_p99_us),
        ],
    ));
    print_table(
        &format!(
            "serve ({} rows x dim {} FN2VEMB1, {}{})",
            serve.n,
            serve.dim,
            fastn2v::util::fmt_bytes(serve.file_bytes),
            if serve.mmap_supported {
                ""
            } else {
                "; mmap unsupported here"
            }
        ),
        &["wall / p50", "p99 / recall"],
        &serve_table,
    );
    let sweep_table: Vec<(String, Vec<String>)> = serve
        .batch_rows
        .iter()
        .map(|r| {
            (
                format!("batch {}", r.batch_max),
                vec![
                    format!("{:.0} q/s", r.queries_per_sec),
                    format!("{} us", r.p50_us),
                    format!("{} us", r.p99_us),
                    format!("{:.1}", r.mean_batch),
                ],
            )
        })
        .collect();
    print_table(
        &format!(
            "serve daemon batch sweep ({} pipelined NN queries over UDS)",
            serve.daemon_queries
        ),
        &["throughput", "p50", "p99", "mean batch"],
        &sweep_table,
    );
    if serve.hnsw_p50_us > 0.0 {
        println!(
            "hnsw query speedup vs brute force (p50): {:.2}x",
            serve.brute_p50_us / serve.hnsw_p50_us
        );
    }

    let secs_of = |name: &str| rows.iter().find(|r| r.name == name).and_then(|r| r.secs);
    let speedup = |a: Option<f64>, b: Option<f64>| match (a, b) {
        (Some(a), Some(b)) if b > 0.0 => Some(a / b),
        _ => None,
    };
    let reject_vs_base = speedup(secs_of("FN-Base"), secs_of("FN-Reject"));
    let reject_vs_cache = speedup(secs_of("FN-Cache/linear"), secs_of("FN-Cache/reject"));
    if let Some(s) = reject_vs_base {
        println!("FN-Reject speedup vs FN-Base: {s:.2}x");
    }
    if let Some(s) = reject_vs_cache {
        println!("reject vs linear sampler (same messaging): {s:.2}x");
    }

    let out_path = std::env::var("FASTN2V_BENCH_OUT").ok();
    if quick && out_path.is_none() {
        println!("--quick: JSON baseline not written (set FASTN2V_BENCH_OUT to force)");
        return;
    }
    let out_path = out_path.unwrap_or_else(|| "../BENCH_walks.json".to_string());
    let json = render_json(
        &g,
        walk_len,
        full,
        &rows,
        reject_vs_base,
        reject_vs_cache,
        hot_threshold,
        &ablation,
        ratio_reduction,
        &amort,
        &store,
        &sgns,
        &ckpt,
        &dist,
        &serve,
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("baseline written to {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}

struct SgnsTrainRow {
    mode: &'static str,
    threads: usize,
    wall_secs: f64,
    steps_per_sec: f64,
    final_loss: f32,
}

struct SgnsTrainBench {
    dim: usize,
    batch: usize,
    negatives: usize,
    steps: u32,
    rows: Vec<SgnsTrainRow>,
}

/// Walk the bench graph once (FN-Cache), then train SGNS over the corpus
/// for every (mode, threads) cell, reporting steps/sec. Each cell gets a
/// fresh model so the work per cell is identical.
fn sgns_train_bench(
    g: &std::sync::Arc<fastn2v::graph::Graph>,
    walk_len: u32,
    quick: bool,
) -> SgnsTrainBench {
    let cfg = FnConfig::new(0.5, 2.0, 3)
        .with_walk_length(walk_len)
        .with_popular_threshold(popular_threshold(g))
        .with_variant(Variant::Cache);
    let session = WalkSession::builder(g.clone(), cfg).workers(4).build();
    let walks = session.collect(&WalkRequest::all()).expect("bench walks").walks;
    let n = g.num_vertices();
    let corpus = Corpus::new(&walks, n);
    let (dim, batch, negatives) = (64usize, 256usize, 5usize);
    let steps: u32 = if quick { 60 } else { 600 };
    let mut rows = Vec::new();
    for mode in [TrainMode::Hogwild, TrainMode::Sharded] {
        for threads in [1usize, 2, 4, 8] {
            let tcfg = TrainConfig {
                steps,
                log_every: steps, // first + last point only
                seed: 7,
                threads,
                mode,
                ..Default::default()
            };
            let mut model = ParallelSgns::from_config(n, dim, &tcfg);
            let t = std::time::Instant::now();
            let curve = model.train(&corpus, &tcfg, batch, negatives);
            let wall_secs = t.elapsed().as_secs_f64();
            rows.push(SgnsTrainRow {
                mode: mode.name(),
                threads,
                wall_secs,
                steps_per_sec: if wall_secs > 0.0 {
                    f64::from(steps) / wall_secs
                } else {
                    0.0
                },
                final_loss: curve.last().map(|l| l.loss).unwrap_or(f32::NAN),
            });
        }
    }
    SgnsTrainBench {
        dim,
        batch,
        negatives,
        steps,
        rows,
    }
}

struct CheckpointBench {
    every: u32,
    plain_secs: f64,
    checkpointed_secs: f64,
    checkpoints_written: u64,
    checkpoint_io_secs: f64,
    file_bytes: u64,
    resume_secs: f64,
}

impl CheckpointBench {
    fn overhead_pct(&self) -> f64 {
        if self.plain_secs > 0.0 {
            (self.checkpointed_secs / self.plain_secs - 1.0) * 100.0
        } else {
            0.0
        }
    }
}

/// Run the same 2-round FN-Cache query plain and checkpointed (the
/// no-crash overhead), then delete every checkpoint but the middle one
/// and time a resume — an interrupted run's recovery latency, including
/// the deterministic replay of the completed units.
fn checkpoint_bench(
    g: &std::sync::Arc<fastn2v::graph::Graph>,
    walk_len: u32,
    quick: bool,
) -> CheckpointBench {
    let dir = std::env::temp_dir().join(format!("fastn2v-bench-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = FnConfig::new(0.5, 2.0, 3)
        .with_walk_length(walk_len)
        .with_popular_threshold(popular_threshold(g))
        .with_variant(Variant::Cache);
    let session = WalkSession::builder(g.clone(), cfg).workers(4).build();
    let req = WalkRequest::all().with_rounds(2);
    let n = g.num_vertices();
    let every = if quick { 2 } else { 4 };

    let t = std::time::Instant::now();
    let plain = session.collect(&req).expect("plain bench walks").walks;
    let plain_secs = t.elapsed().as_secs_f64();

    let mut ckpt_cfg = CheckpointCfg::new(&dir, every);
    ckpt_cfg.keep_all = true;
    let mut sink = CollectSink::new(n);
    let t = std::time::Instant::now();
    let q = session
        .run_checkpointed(&req, &mut sink, &ckpt_cfg)
        .expect("checkpointed bench walks");
    let checkpointed_secs = t.elapsed().as_secs_f64();
    assert_eq!(sink.walks(), &plain, "checkpointed bench run diverged");

    // Keep only the middle checkpoint: the resume below replays the done
    // units and restores mid-unit state, as after a real interruption.
    let files = checkpoint_files(&dir);
    let file_bytes = files
        .last()
        .and_then(|f| std::fs::metadata(f).ok())
        .map(|m| m.len())
        .unwrap_or(0);
    let mid = files.len() / 2;
    for (i, f) in files.iter().enumerate() {
        if i != mid {
            let _ = std::fs::remove_file(f);
        }
    }
    let resume_cfg = CheckpointCfg::new(&dir, u32::MAX);
    let mut rsink = CollectSink::new(n);
    let t = std::time::Instant::now();
    session
        .resume(&req, &mut rsink, &resume_cfg)
        .expect("resumed bench walks");
    let resume_secs = t.elapsed().as_secs_f64();
    assert_eq!(rsink.walks(), &plain, "resumed bench run diverged");
    let _ = std::fs::remove_dir_all(&dir);

    CheckpointBench {
        every,
        plain_secs,
        checkpointed_secs,
        checkpoints_written: q.metrics.checkpoints_written,
        checkpoint_io_secs: q.metrics.checkpoint_secs,
        file_bytes,
        resume_secs,
    }
}

struct DistRow {
    shards: usize,
    wall_secs: f64,
    bytes_remote: u64,
}

struct DistributedBench {
    workers_per_shard: usize,
    plain_secs: f64,
    rows: Vec<DistRow>,
}

/// Run the same FN-Cache query single-process and as in-proc shard
/// fleets at 1/2/4 shards. Every fleet shape must produce bit-identical
/// walks (the §Distributed conformance bar), so the wall-clock delta is
/// pure sharding overhead: frame encode/decode plus the per-superstep
/// barrier round-trip through the coordinator.
fn distributed_bench(
    g: &std::sync::Arc<fastn2v::graph::Graph>,
    walk_len: u32,
) -> DistributedBench {
    const WORKERS_PER_SHARD: usize = 2;
    let cfg = FnConfig::new(0.5, 2.0, 3)
        .with_walk_length(walk_len)
        .with_popular_threshold(popular_threshold(g))
        .with_variant(Variant::Cache);
    let req = WalkRequest::all();

    let session = WalkSession::builder(g.clone(), cfg).workers(4).build();
    let t = std::time::Instant::now();
    let plain = session.collect(&req).expect("plain bench walks").walks;
    let plain_secs = t.elapsed().as_secs_f64();

    let mut rows = Vec::new();
    for shards in [1usize, 2, 4] {
        let fleet = WalkSession::builder(g.clone(), cfg)
            .workers(WORKERS_PER_SHARD)
            .distributed(DistConfig::new(shards, WORKERS_PER_SHARD))
            .build();
        let t = std::time::Instant::now();
        let out = fleet.collect(&req).expect("sharded bench walks");
        let wall_secs = t.elapsed().as_secs_f64();
        assert_eq!(out.walks, plain, "sharded bench run diverged at {shards} shard(s)");
        rows.push(DistRow {
            shards,
            wall_secs,
            bytes_remote: out.metrics.total_remote_bytes(),
        });
    }
    DistributedBench {
        workers_per_shard: WORKERS_PER_SHARD,
        plain_secs,
        rows,
    }
}

struct StoreModeRow {
    name: &'static str,
    open_secs: f64,
    first_walk_secs: f64,
}

struct GraphStoreBench {
    file_bytes: u64,
    write_secs: f64,
    mmap_supported: bool,
    rows: Vec<StoreModeRow>,
}

/// Write the bench graph as FN2VGRF2 once, then measure per open mode:
/// time-to-open (decode vs map vs map-trusted) and time from open to the
/// first walk of a one-seed query through a fresh `WalkSession`.
fn graph_store_bench(g: &fastn2v::graph::Graph, walk_len: u32) -> GraphStoreBench {
    let dir = std::env::temp_dir().join("fastn2v-bench-store");
    std::fs::create_dir_all(&dir).ok();
    let path = dir.join(format!("walk_engines-{}.fn2v", std::process::id()));
    let t = std::time::Instant::now();
    write_v2(g, &path).expect("write FN2VGRF2");
    let write_secs = t.elapsed().as_secs_f64();
    let file_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    let mmap_supported = Mmap::supported();

    let modes: [(&'static str, OpenOptions); 3] = [
        ("owned", OpenOptions::owned()),
        ("mmap", OpenOptions::mapped()),
        ("mmap-trusted", OpenOptions::mapped().trusted(true)),
    ];
    let mut rows = Vec::new();
    for (name, opts) in modes {
        if name != "owned" && !mmap_supported {
            continue;
        }
        let t = std::time::Instant::now();
        let graph = open_graph(&path, &opts).expect("open FN2VGRF2");
        let open_secs = t.elapsed().as_secs_f64();
        let t = std::time::Instant::now();
        let cfg = FnConfig::new(0.5, 2.0, 3)
            .with_walk_length(walk_len)
            .with_popular_threshold(popular_threshold(&graph))
            .with_variant(Variant::Cache);
        let session = WalkSession::builder(std::sync::Arc::new(graph), cfg)
            .workers(4)
            .build();
        let req = WalkRequest::all().with_seeds(SeedSet::Explicit(vec![0]));
        let _ = session.collect(&req).expect("one-seed query");
        let first_walk_secs = t.elapsed().as_secs_f64();
        rows.push(StoreModeRow {
            name,
            open_secs,
            first_walk_secs,
        });
    }
    std::fs::remove_file(&path).ok();
    GraphStoreBench {
        file_bytes,
        write_secs,
        mmap_supported,
        rows,
    }
}

struct ServeBatchRow {
    batch_max: usize,
    queries_per_sec: f64,
    p50_us: u64,
    p99_us: u64,
    mean_batch: f64,
}

struct ServeBench {
    n: usize,
    dim: usize,
    file_bytes: u64,
    mmap_supported: bool,
    write_secs: f64,
    open_owned_secs: f64,
    open_mapped_secs: Option<f64>,
    hnsw_build_secs: f64,
    recall_at_10: f64,
    nn_queries: usize,
    brute_p50_us: f64,
    brute_p99_us: f64,
    hnsw_p50_us: f64,
    hnsw_p99_us: f64,
    daemon_queries: usize,
    batch_rows: Vec<ServeBatchRow>,
}

/// Nearest-rank percentile over an unsorted sample, in microseconds.
fn pctile_us(samples: &mut [f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((samples.len() as f64 - 1.0) * p).round() as usize;
    samples[idx.min(samples.len() - 1)]
}

/// Deterministic filler rows (splitmix64 per element, values in
/// [-0.5, 0.5)): uniform random vectors are HNSW's worst case, so the
/// recall and latency below are conservative relative to trained
/// embeddings, and the bench never pays an SGNS run.
fn synth_flat(n: usize, dim: usize, seed: u64) -> Vec<f32> {
    let mut out = Vec::with_capacity(n * dim);
    for i in 0..(n * dim) as u64 {
        let mut z = seed.wrapping_add((i + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        out.push(((z >> 40) as f32) / (1u64 << 24) as f32 - 0.5);
    }
    out
}

/// Measure the serving stack over one FN2VEMB1 file sized to the bench
/// graph: atomic write, owned vs mapped reopen, HNSW build + recall@10
/// vs `nearest_flat`, per-query NN latency brute vs indexed, then a
/// daemon batch-size sweep — the same pipelined-client pattern `serve
/// query --count N` uses, so `mean_batch` shows the batcher actually
/// coalescing under depth.
fn serve_bench(g: &std::sync::Arc<fastn2v::graph::Graph>, quick: bool) -> ServeBench {
    let dir = std::env::temp_dir().join(format!("fastn2v-bench-serve-{}", std::process::id()));
    std::fs::create_dir_all(&dir).ok();
    let emb_path = dir.join("bench.emb");
    let n = g.num_vertices();
    let dim = 64usize;
    let flat = synth_flat(n, dim, 0xEB5E);

    let t = std::time::Instant::now();
    write_emb(&emb_path, &flat, dim, 0xBE9C).expect("write FN2VEMB1");
    let write_secs = t.elapsed().as_secs_f64();
    let file_bytes = std::fs::metadata(&emb_path).map(|m| m.len()).unwrap_or(0);

    let t = std::time::Instant::now();
    let emb = EmbStore::open(&emb_path, &OpenOptions::owned()).expect("open owned");
    let open_owned_secs = t.elapsed().as_secs_f64();
    let mmap_supported = Mmap::supported();
    let open_mapped_secs = if mmap_supported {
        let t = std::time::Instant::now();
        let mapped = EmbStore::open(&emb_path, &OpenOptions::mapped()).expect("open mapped");
        let secs = t.elapsed().as_secs_f64();
        assert!(mapped.is_mapped(), "mapped bench open fell back to owned");
        Some(secs)
    } else {
        None
    };

    let params = HnswParams::default();
    let t = std::time::Instant::now();
    let idx = HnswIndex::build(&flat, dim, &params);
    let hnsw_build_secs = t.elapsed().as_secs_f64();
    let idx_path = dir.join("bench.emb.idx");
    idx.save(&idx_path, emb.header_checksum())
        .expect("save FN2VIDX1 sidecar");

    let nn_queries = if quick { 64 } else { 256 };
    let queries: Vec<usize> = (0..nn_queries).map(|i| i * n / nn_queries).collect();
    let recall_at_10 = recall_at_k(&idx, &flat, dim, 10, params.ef_search, &queries);

    let mut brute_us = Vec::with_capacity(queries.len());
    let mut hnsw_us = Vec::with_capacity(queries.len());
    for &v in &queries {
        let t = std::time::Instant::now();
        let truth = fastn2v::embed::nearest_flat(&flat, dim, v, 10);
        brute_us.push(t.elapsed().as_secs_f64() * 1e6);
        let t = std::time::Instant::now();
        let got = idx.search(
            &flat,
            &flat[v * dim..(v + 1) * dim],
            10,
            params.ef_search,
            Some(v as u32),
        );
        hnsw_us.push(t.elapsed().as_secs_f64() * 1e6);
        assert_eq!(truth.len(), got.len(), "bench query shape diverged");
    }
    let brute_p50_us = pctile_us(&mut brute_us, 0.50);
    let brute_p99_us = pctile_us(&mut brute_us, 0.99);
    let hnsw_p50_us = pctile_us(&mut hnsw_us, 0.50);
    let hnsw_p99_us = pctile_us(&mut hnsw_us, 0.99);

    // Daemon sweep: same query load at three drain sizes. Each point gets
    // a fresh daemon (the core consumes the store); the index reloads
    // from the sidecar so only batch_max varies across points.
    let daemon_queries = if quick { 64 } else { 512 };
    let mut batch_rows = Vec::new();
    for batch_max in [1usize, 8, 64] {
        let emb = EmbStore::open(&emb_path, &OpenOptions::owned()).expect("open for daemon");
        let idx = HnswIndex::load(&idx_path, emb.header_checksum(), emb.n(), emb.dim())
            .expect("load FN2VIDX1 sidecar");
        let sock = dir.join(format!("bench-{batch_max}.sock"));
        let _ = std::fs::remove_file(&sock);
        let listener =
            std::os::unix::net::UnixListener::bind(&sock).expect("bind bench serve socket");
        let core = ServeCore::new(emb, Some(idx), None, params.ef_search);
        let opts = ServeOpts {
            batch_max,
            ..ServeOpts::default()
        };
        let sock_srv = sock.clone();
        let server = std::thread::spawn(move || run_server(listener, &sock_srv, core, opts));
        let (mut client, hello) = ServeClient::connect(&sock).expect("connect bench client");
        assert!(hello.has_index, "bench daemon lost its index");
        let t = std::time::Instant::now();
        for i in 0..daemon_queries {
            let v = ((i * n / daemon_queries) % n) as u32;
            client
                .send(&ServeRequest::Nearest { v, k: 10 })
                .expect("send bench query");
        }
        for _ in 0..daemon_queries {
            let (_, reply) = client.recv().expect("recv bench reply");
            reply.expect("bench daemon rejected an admitted query");
        }
        let wall = t.elapsed().as_secs_f64();
        let snap = client.stats().expect("bench stats");
        client.shutdown().expect("bench shutdown");
        server
            .join()
            .expect("bench server thread")
            .expect("bench server io");
        batch_rows.push(ServeBatchRow {
            batch_max,
            queries_per_sec: if wall > 0.0 {
                daemon_queries as f64 / wall
            } else {
                0.0
            },
            p50_us: snap.nearest.p50_us,
            p99_us: snap.nearest.p99_us,
            mean_batch: snap.mean_batch(),
        });
    }
    let _ = std::fs::remove_dir_all(&dir);

    ServeBench {
        n,
        dim,
        file_bytes,
        mmap_supported,
        write_secs,
        open_owned_secs,
        open_mapped_secs,
        hnsw_build_secs,
        recall_at_10,
        nn_queries,
        brute_p50_us,
        brute_p99_us,
        hnsw_p50_us,
        hnsw_p99_us,
        daemon_queries,
        batch_rows,
    }
}

/// Hand-rolled JSON (serde is unavailable offline); schema documented in
/// EXPERIMENTS.md §Perf, §Partitioning and §Scale.
#[allow(clippy::too_many_arguments)]
fn render_json(
    g: &fastn2v::graph::Graph,
    walk_len: u32,
    full: bool,
    rows: &[Row],
    reject_vs_base: Option<f64>,
    reject_vs_cache: Option<f64>,
    hot_threshold: u32,
    ablation: &[PartitionAblationRow],
    ratio_reduction: Option<f64>,
    amort: &SessionAmortization,
    store: &GraphStoreBench,
    sgns: &SgnsTrainBench,
    ckpt: &CheckpointBench,
    dist: &DistributedBench,
    serve: &ServeBench,
) -> String {
    let stats = g.stats();
    let fmt_opt = |o: Option<f64>| o.map(|v| format!("{v:.3}")).unwrap_or_else(|| "null".into());
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"walk_engines\",\n");
    s.push_str("  \"status\": \"measured\",\n");
    s.push_str(&format!("  \"full_scale\": {full},\n"));
    s.push_str(&format!(
        "  \"graph\": {{\"family\": \"rmat-skew-4\", \"vertices\": {}, \"edges\": {}, \"max_degree\": {}, \"walk_length\": {walk_len}}},\n",
        stats.num_vertices, stats.num_edges, stats.max_degree
    ));
    s.push_str("  \"engines\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let secs = r
            .secs
            .map(|v| format!("{v:.6}"))
            .unwrap_or_else(|| "null".into());
        let msteps = r
            .msteps
            .map(|v| format!("{v:.4}"))
            .unwrap_or_else(|| "null".into());
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"wall_secs\": {secs}, \"msteps_per_sec\": {msteps}}}{}\n",
            r.name,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"partitioning\": {{\"workers\": {ABLATION_WORKERS}, \"hot_degree_threshold\": {hot_threshold}, \"rows\": [\n"
    ));
    for (i, r) in ablation.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"scheme\": \"{}\", \"hot_split\": {}, \"wall_secs\": {:.6}, \"aggregate_imbalance\": {:.4}, \"worst_imbalance\": {:.4}, \"hot_tasks\": {}}}{}\n",
            r.scheme,
            r.hot_split,
            r.wall_secs,
            r.aggregate_imbalance,
            r.worst_imbalance,
            r.hot_tasks,
            if i + 1 < ablation.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]},\n");
    s.push_str(&format!(
        "  \"imbalance_reduction_degree_hot_vs_hash\": {},\n",
        fmt_opt(ratio_reduction)
    ));
    s.push_str(&format!(
        "  \"speedup_reject_vs_base\": {},\n",
        fmt_opt(reject_vs_base)
    ));
    s.push_str(&format!(
        "  \"speedup_reject_vs_linear_same_messaging\": {},\n",
        fmt_opt(reject_vs_cache)
    ));
    s.push_str(&format!(
        "  \"graph_store\": {{\"format\": \"FN2VGRF2\", \"file_bytes\": {}, \"write_secs\": {:.6}, \"mmap_supported\": {}, \"modes\": [\n",
        store.file_bytes, store.write_secs, store.mmap_supported
    ));
    for (i, r) in store.rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"open_secs\": {:.6}, \"first_walk_secs\": {:.6}}}{}\n",
            r.name,
            r.open_secs,
            r.first_walk_secs,
            if i + 1 < store.rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]},\n");
    s.push_str(&format!(
        "  \"sgns_train\": {{\"dim\": {}, \"batch\": {}, \"negatives\": {}, \"steps\": {}, \"rows\": [\n",
        sgns.dim, sgns.batch, sgns.negatives, sgns.steps
    ));
    for (i, r) in sgns.rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"mode\": \"{}\", \"threads\": {}, \"wall_secs\": {:.6}, \"steps_per_sec\": {:.2}, \"final_loss\": {:.4}}}{}\n",
            r.mode,
            r.threads,
            r.wall_secs,
            r.steps_per_sec,
            r.final_loss,
            if i + 1 < sgns.rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]},\n");
    s.push_str(&format!(
        "  \"checkpoint\": {{\"every_supersteps\": {}, \"plain_secs\": {:.6}, \"checkpointed_secs\": {:.6}, \"overhead_pct\": {:.2}, \"checkpoints_written\": {}, \"checkpoint_io_secs\": {:.6}, \"file_bytes\": {}, \"resume_secs\": {:.6}}},\n",
        ckpt.every,
        ckpt.plain_secs,
        ckpt.checkpointed_secs,
        ckpt.overhead_pct(),
        ckpt.checkpoints_written,
        ckpt.checkpoint_io_secs,
        ckpt.file_bytes,
        ckpt.resume_secs
    ));
    s.push_str(&format!(
        "  \"distributed\": {{\"transport\": \"inproc\", \"workers_per_shard\": {}, \"single_process_secs\": {:.6}, \"rows\": [\n",
        dist.workers_per_shard, dist.plain_secs
    ));
    for (i, r) in dist.rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"shards\": {}, \"wall_secs\": {:.6}, \"bytes_remote\": {}}}{}\n",
            r.shards,
            r.wall_secs,
            r.bytes_remote,
            if i + 1 < dist.rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]},\n");
    s.push_str(&format!(
        "  \"serve\": {{\"format\": \"FN2VEMB1\", \"rows\": {}, \"dim\": {}, \"file_bytes\": {}, \"mmap_supported\": {}, \"emb_write_secs\": {:.6}, \"emb_open_owned_secs\": {:.6}, \"emb_open_mmap_secs\": {}, \"hnsw_build_secs\": {:.6}, \"recall_at_10\": {:.4}, \"nn_queries\": {}, \"brute_p50_us\": {:.1}, \"brute_p99_us\": {:.1}, \"hnsw_p50_us\": {:.1}, \"hnsw_p99_us\": {:.1}, \"daemon_queries\": {}, \"batch_sweep\": [\n",
        serve.n,
        serve.dim,
        serve.file_bytes,
        serve.mmap_supported,
        serve.write_secs,
        serve.open_owned_secs,
        serve
            .open_mapped_secs
            .map(|v| format!("{v:.6}"))
            .unwrap_or_else(|| "null".into()),
        serve.hnsw_build_secs,
        serve.recall_at_10,
        serve.nn_queries,
        serve.brute_p50_us,
        serve.brute_p99_us,
        serve.hnsw_p50_us,
        serve.hnsw_p99_us,
        serve.daemon_queries
    ));
    for (i, r) in serve.batch_rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"batch_max\": {}, \"queries_per_sec\": {:.1}, \"p50_us\": {}, \"p99_us\": {}, \"mean_batch\": {:.2}}}{}\n",
            r.batch_max,
            r.queries_per_sec,
            r.p50_us,
            r.p99_us,
            r.mean_batch,
            if i + 1 < serve.batch_rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]},\n");
    s.push_str(&format!(
        "  \"session_amortization\": {{\"queries\": {}, \"seeds_per_query\": {}, \"reuse_secs\": {:.6}, \"rebuild_secs\": {:.6}, \"speedup\": {:.3}}}\n",
        amort.queries,
        amort.seeds_per_query,
        amort.reuse_secs,
        amort.rebuild_secs,
        amort.speedup()
    ));
    s.push_str("}\n");
    s
}
