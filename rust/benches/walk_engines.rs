//! End-to-end walk-engine comparison (the paper's Figure 7/13 axis): all
//! FN variants plus both baselines on a skewed graph, reported as wall
//! time and steps/second.
//!
//! Run: `cargo bench --bench walk_engines`
//! (FASTN2V_BENCH_FULL=1 for a larger graph.)

use fastn2v::exp::common::{run_solution, Solution};
use fastn2v::gen::{skew_graph, GenConfig};
use fastn2v::node2vec::Variant;
use fastn2v::util::benchkit::print_table;

fn main() {
    let full = std::env::var("FASTN2V_BENCH_FULL").is_ok();
    let (n, deg, walk_len) = if full {
        (1 << 17, 100, 80u32)
    } else {
        (1 << 13, 40, 20u32)
    };
    let g = skew_graph(&GenConfig::new(n, deg, 11), 4.0);
    let stats = g.stats();
    println!(
        "graph: |V|={} |E|={} max deg {} | walk length {walk_len}",
        stats.num_vertices, stats.num_edges, stats.max_degree
    );
    let total_steps = (stats.num_vertices * walk_len as u64) as f64;

    let mut rows = Vec::new();
    for sol in [
        Solution::CNode2Vec,
        Solution::Spark,
        Solution::Fn(Variant::Base),
        Solution::Fn(Variant::Local),
        Solution::Fn(Variant::Switch),
        Solution::Fn(Variant::Cache),
        Solution::Fn(Variant::Approx),
    ] {
        let out = run_solution(sol, &g, 0.5, 2.0, walk_len, 3, false);
        let cells = match out.secs() {
            Some(s) => vec![
                fastn2v::util::fmt_secs(s),
                format!("{:.2} M steps/s", total_steps / s / 1e6),
            ],
            None => vec![out.cell(), "-".into()],
        };
        rows.push((sol.name().to_string(), cells));
    }
    print_table("walk engines (skew-4 graph)", &["wall", "throughput"], &rows);
}
