//! Microbenchmarks for the hot paths identified in DESIGN.md §Perf:
//! 2nd-order transition weight computation (the per-step inner loop),
//! alias table construction/sampling, RNG stream derivation, and the
//! Pregel engine's per-superstep overhead.
//!
//! Run: `cargo bench --bench microbench` (FASTN2V_BENCH_ITERS to adjust).

use fastn2v::gen::{skew_graph, GenConfig};
use fastn2v::graph::partition::Partitioner;
use fastn2v::node2vec::transition::fill_second_order_weights;
use fastn2v::pregel::{Ctx, Engine, EngineOpts, Message, VertexProgram};
use fastn2v::util::alias::AliasTable;
use fastn2v::util::benchkit::{bench, black_box, report, BenchConfig};
use fastn2v::util::rng::{stream, Xoshiro256pp};

fn bench_transition_weights(cfg: BenchConfig) {
    let g = skew_graph(&GenConfig::new(1 << 14, 60, 7), 4.0);
    // Pick a heavy vertex and a light predecessor.
    let v = g
        .vertices()
        .max_by_key(|&v| g.degree(v))
        .unwrap();
    let u = *g.neighbors(v).iter().min_by_key(|&&u| g.degree(u)).unwrap();
    let mut scratch = Vec::new();
    let m = bench(
        &format!("fill_second_order_weights d_v={} d_u={}", g.degree(v), g.degree(u)),
        BenchConfig {
            warmup_iters: 100,
            measure_iters: cfg.measure_iters.max(1000),
        },
        || {
            fill_second_order_weights(
                g.neighbors(v),
                g.weights(v),
                u,
                g.neighbors(u),
                0.5,
                2.0,
                &mut scratch,
            );
            black_box(&scratch);
        },
    );
    report(&m);
    // Reverse asymmetry: popular predecessor (gallop path).
    let m = bench(
        &format!("fill_second_order_weights d_v={} d_u={} (gallop)", g.degree(u), g.degree(v)),
        BenchConfig {
            warmup_iters: 100,
            measure_iters: cfg.measure_iters.max(1000),
        },
        || {
            fill_second_order_weights(
                g.neighbors(u),
                g.weights(u),
                v,
                g.neighbors(v),
                0.5,
                2.0,
                &mut scratch,
            );
            black_box(&scratch);
        },
    );
    report(&m);
}

fn bench_alias(cfg: BenchConfig) {
    let weights: Vec<f32> = (1..=1000).map(|i| (i % 17) as f32 + 0.5).collect();
    let m = bench("alias_build_1000", cfg, || {
        black_box(AliasTable::new(&weights).unwrap());
    });
    report(&m);
    let table = AliasTable::new(&weights).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let m = bench(
        "alias_sample_x10000",
        BenchConfig {
            warmup_iters: 10,
            measure_iters: cfg.measure_iters.max(100),
        },
        || {
            let mut acc = 0usize;
            for _ in 0..10_000 {
                acc += table.sample(&mut rng);
            }
            black_box(acc);
        },
    );
    report(&m);
}

fn bench_rng(cfg: BenchConfig) {
    let m = bench(
        "stream_derivation_x10000",
        BenchConfig {
            warmup_iters: 10,
            measure_iters: cfg.measure_iters.max(100),
        },
        || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                let mut s = stream(42, i, i ^ 7, 2);
                acc ^= s.next_u64();
            }
            black_box(acc);
        },
    );
    report(&m);
}

/// Engine overhead: a no-op program over a mid-sized graph.
struct Noop;
struct NoopMsg;
impl Message for NoopMsg {
    fn wire_bytes(&self) -> u64 {
        4
    }
}
impl VertexProgram for Noop {
    type Value = u64;
    type Msg = NoopMsg;
    fn compute(&self, ctx: &mut Ctx<'_, Self>, _vid: u32, _v: &mut u64, _m: &mut Vec<NoopMsg>) {
        if ctx.superstep() >= 10 {
            ctx.vote_to_halt();
        }
    }
}

fn bench_engine_overhead(cfg: BenchConfig) {
    let g = skew_graph(&GenConfig::new(1 << 14, 10, 9), 2.0);
    let m = bench("engine_10_supersteps_16k_vertices", cfg, || {
        let eng = Engine::new(&g, Partitioner::hash(8), Noop, EngineOpts::default());
        black_box(eng.run().unwrap().metrics.num_supersteps());
    });
    report(&m);
}

fn main() {
    let cfg = BenchConfig::from_env();
    bench_transition_weights(cfg);
    bench_alias(cfg);
    bench_rng(cfg);
    bench_engine_overhead(cfg);
}
