//! Figure-regeneration bench: runs every table/figure driver at quick
//! scale so `cargo bench` exercises the full harness. Full-scale runs
//! (the numbers recorded in EXPERIMENTS.md) are produced with
//! `fastn2v fig --id all`.

use fastn2v::exp::common::Scale;
use fastn2v::exp::figures;
use fastn2v::util::benchkit::time_once;

fn main() {
    let scale = if std::env::var("FASTN2V_BENCH_FULL").is_ok() {
        Scale::Full
    } else {
        Scale::Quick
    };
    let seed = 42;
    macro_rules! run {
        ($name:literal, $e:expr) => {{
            let (_, secs) = time_once(|| $e);
            println!("figure driver {:8} {}", $name, fastn2v::util::fmt_secs(secs));
        }};
    }
    run!("table1", figures::table1(scale, seed));
    run!("fig1", figures::fig1(scale, seed));
    run!("fig4", figures::fig4(scale, seed));
    run!("fig5", figures::fig5(scale, seed));
    run!("fig6", figures::fig6(scale, seed));
    run!("fig7", figures::fig7(scale, seed));
    run!("fig8", figures::fig8(scale, seed));
    run!("fig9", figures::fig9(scale, seed));
    run!("fig10/11", figures::fig10(scale, seed));
    run!("fig12", figures::fig12(scale, seed));
    run!("fig13", figures::fig13(scale, seed));
    run!("fig14", figures::fig14(scale, seed));
}
